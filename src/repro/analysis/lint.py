"""reprolint — the jit-discipline linter (stdlib ``ast``, no deps).

The engine's correctness rests on conventions nothing else enforces:
jit-clean BSP loops (a single ``.item()`` in a hot path turns an async
dispatch pipeline into a per-iteration host round trip), int32-pinned
integer accumulators (under ``jax_enable_x64`` an unpinned ``jnp.sum``
promotes to int64 and poisons carried state — the exact drift class
PR 6 fixed by hand), fenced timing (an unfenced ``time.monotonic`` pair
measures enqueue latency, not execution), and diagnostics routed through
``repro.obs.log`` (a bare ``print`` in library code cannot be silenced
in a serving loop). Every rule below encodes one of those conventions.

Rules
  RL001 host-sync-in-traced   ``.item()``/``.tolist()``, ``int()``/
                              ``bool()``/``float()`` over array
                              expressions, or ``np.asarray``/``np.array``
                              of device values inside a traced region
                              (a jitted function, a ``lax`` control-flow
                              body, a Pallas kernel, or anything nested
                              in one).
  RL002 tracer-branch         Python ``if``/``while`` over an array
                              expression, or ``for`` over an array
                              iterable, inside a traced region — a
                              retrace storm or a ConcretizationError
                              waiting for the first untested config.
  RL003 unpinned-int-accum    ``jnp.sum``/``cumsum``/``prod``/
                              ``count_nonzero`` without ``dtype=`` over
                              a bool/int-flavored operand and without an
                              immediate ``.astype`` re-pin (x64 drift).
  RL004 unfenced-timing       a wall-clock measurement (two timing calls
                              or a timing subtraction) with no
                              ``block_until_ready`` / ``span`` /
                              ``timed`` fence inside the measured region.
  RL005 bare-diagnostic       ``print(...)`` or ``warnings.warn(...)``
                              in library code (under ``src/repro``) —
                              route through ``repro.obs.log``.
  RL006 swallowed-exception   a bare ``except:`` that never re-raises, or
                              an ``except Exception/BaseException`` whose
                              body is only ``pass``/``...``/``continue``.
                              Blanket swallowing hides the exact faults
                              the robustness layer exists to surface;
                              legitimate boundaries (the retry/degrade
                              ladder) declare themselves with a
                              ``# reprolint: disable=RL006 -- why``.

Suppression syntax (same line or the line above)::

    total = jnp.sum(counts)     # reprolint: disable=RL003 -- host-only
    # reprolint: disable=RL004,RL005
    # reprolint: skip-file          (first 10 lines: skip whole file)

A bare ``# reprolint: disable`` suppresses every rule on that line.
Suppressions are deliberate, reviewable markers — each one should carry
a trailing reason, the way the shipped tree's do.

CLI::

    python -m repro.analysis.lint [paths ...] [--select RL00x,...]
        [--json] [--statistics] [--lib-root PREFIX]

Exit status 1 when findings remain, 0 on a clean tree. Rule detection
is intentionally syntactic and calibrated to this codebase: it cannot
prove an expression is a tracer, only that it is array-flavored in a
region that traces — which is exactly the review question a human would
ask, automated.
"""
from __future__ import annotations

import argparse
import ast
import json
import re
import sys
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Iterable, Optional

RULES = {
    "RL001": "host sync inside a traced region",
    "RL002": "Python control flow over an array value in a traced region",
    "RL003": "integer/bool accumulation without a pinned dtype",
    "RL004": "wall-clock timing without a fence in the measured region",
    "RL005": "bare print()/warnings.warn() in library code",
    "RL006": "exception swallowed outside a declared retry boundary",
}

_SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*disable(?:=\s*([A-Za-z0-9_,\s]+?))?\s*(?:--|$)")
_SKIP_FILE_RE = re.compile(r"#\s*reprolint:\s*skip-file")

# --- syntactic vocabulary -------------------------------------------------

_TIMING_FNS = {"time.monotonic", "time.monotonic_ns", "time.time",
               "time.perf_counter", "time.perf_counter_ns"}
_FENCE_ATTR = "block_until_ready"
_FENCE_CALLS = {"timed", "span", "timed_span"}
# calls whose function-valued arguments are traced by JAX
_TRACING_WRAPPERS = {"jit", "vmap", "pmap", "while_loop", "fori_loop",
                     "scan", "cond", "switch", "map", "shard_map",
                     "pallas_call", "checkpoint", "remat", "grad",
                     "value_and_grad"}
_ACCUM_FNS = {"jnp.sum", "jnp.cumsum", "jnp.prod", "jnp.count_nonzero",
              "jax.numpy.sum", "jax.numpy.cumsum", "jax.numpy.prod",
              "jax.numpy.count_nonzero"}
_ARRAY_METHODS = {"any", "all", "sum", "min", "max", "mean", "astype",
                  "argmax", "argmin", "item", "nonzero", "ravel", "dot"}
# jnp calls that return static Python values — never tracers
_STATIC_JNP = {"jnp.issubdtype", "jnp.dtype", "jnp.result_type",
               "jnp.iinfo", "jnp.finfo", "jnp.shape", "jnp.ndim",
               "jnp.size", "jnp.promote_types"}
_INT_DTYPES = {"int8", "int16", "int32", "int64",
               "uint8", "uint16", "uint32", "uint64"}
_BOOL_DTYPES = {"bool", "bool_"}
_BOOL_CALLS = {"jnp.logical_and", "jnp.logical_or", "jnp.logical_not",
               "jnp.logical_xor", "jnp.isin", "jnp.isnan", "jnp.isfinite",
               "jnp.isinf", "jnp.isclose", "jnp.equal", "jnp.not_equal",
               "jnp.greater", "jnp.less", "jnp.greater_equal",
               "jnp.less_equal"}
_NP_CAST = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}


@dataclass
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def _dotted(node) -> Optional[str]:
    """'jax.lax.fori_loop' for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_arrayish(expr: ast.AST) -> bool:
    """Heuristic: does this expression produce / consume a jnp array?"""
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Call):
            d = _dotted(sub.func)
            if d is not None:
                if d in _STATIC_JNP:
                    continue
                root = d.split(".", 1)[0]
                if root in ("jnp", "lax") or d.startswith(("jax.numpy.",
                                                          "jax.lax.")):
                    return True
            if (isinstance(sub.func, ast.Attribute)
                    and sub.func.attr in _ARRAY_METHODS):
                return True
    return False


def _astype_flavor(call: ast.Call) -> Optional[str]:
    """'int' / 'bool' when ``call`` is ``x.astype(<that dtype>)``."""
    if not (isinstance(call.func, ast.Attribute)
            and call.func.attr == "astype" and call.args):
        return None
    arg = call.args[0]
    name = _dotted(arg)
    leaf = name.rsplit(".", 1)[-1] if name else None
    if leaf in _INT_DTYPES:
        return "int"
    if leaf in _BOOL_DTYPES:
        return "bool"
    return None


def _flavor(expr: ast.AST, env: dict) -> Optional[str]:
    """'int' | 'bool' | None — the syntactic integer-ness of ``expr``.
    ``env`` maps local names to flavors (single-pass assignment scan)."""
    if isinstance(expr, ast.Compare):
        return "bool"
    if isinstance(expr, ast.BoolOp):
        return "bool"
    if isinstance(expr, ast.UnaryOp) and isinstance(expr.op,
                                                    (ast.Invert, ast.Not)):
        return "bool"
    if isinstance(expr, ast.BinOp):
        if isinstance(expr.op, (ast.BitAnd, ast.BitOr, ast.BitXor)):
            return "bool"
        if isinstance(expr.op, (ast.Add, ast.Sub, ast.Mult)):
            return (_flavor(expr.left, env) or _flavor(expr.right, env))
    if isinstance(expr, ast.Call):
        f = _astype_flavor(expr)
        if f is not None:
            return f
        d = _dotted(expr.func)
        if d in _BOOL_CALLS:
            return "bool"
    if isinstance(expr, ast.Name):
        return env.get(expr.id)
    return None


def _scope_nodes(body: Iterable[ast.stmt]):
    """All nodes in a function/module body WITHOUT descending into nested
    function definitions (they are their own scopes)."""
    stack = list(body)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            stack.append(child)


class _FileLinter:
    def __init__(self, path: str, source: str, *, lib: bool,
                 select: Optional[set] = None):
        self.path = path
        self.source = source
        self.lib = lib
        self.select = select or set(RULES)
        self.findings: list[Finding] = []
        self.lines = source.splitlines()
        self.suppressions = self._scan_suppressions()
        self.tree = ast.parse(source, filename=path)
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child._rl_parent = node
        self.traced = self._collect_traced()

    # -- suppression handling ---------------------------------------------

    def _scan_suppressions(self) -> dict:
        out: dict[int, set] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if m:
                ids = m.group(1)
                out[i] = ({s.strip().upper() for s in ids.split(",")
                           if s.strip()} if ids else {"*"})
        return out

    def _suppressed(self, line: int, rule: str) -> bool:
        for ln in (line, line - 1):
            ids = self.suppressions.get(ln)
            if ids and ("*" in ids or rule in ids):
                return True
        return False

    def _flag(self, node: ast.AST, rule: str, message: str) -> None:
        if rule not in self.select:
            return
        line = getattr(node, "lineno", 1)
        if self._suppressed(line, rule):
            return
        self.findings.append(Finding(self.path, line,
                                     getattr(node, "col_offset", 0),
                                     rule, message))

    # -- traced-region discovery ------------------------------------------

    def _collect_traced(self) -> set:
        """Function/Lambda nodes that JAX traces: jit-decorated, or passed
        (directly or via functools.partial) to a lax control-flow /
        pallas_call / transform wrapper."""
        defs_by_name: dict[str, list] = {}
        traced: set[int] = set()
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs_by_name.setdefault(node.name, []).append(node)
                for dec in node.decorator_list:
                    if self._is_jit_decorator(dec):
                        traced.add(id(node))

        def mark(arg):
            if isinstance(arg, ast.Lambda):
                traced.add(id(arg))
            elif isinstance(arg, ast.Name):
                for d in defs_by_name.get(arg.id, ()):
                    traced.add(id(d))
            elif isinstance(arg, ast.Call):
                d = _dotted(arg.func)
                if d and d.rsplit(".", 1)[-1] == "partial" and arg.args:
                    mark(arg.args[0])

        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            if d and d.rsplit(".", 1)[-1] in _TRACING_WRAPPERS:
                for arg in node.args:
                    mark(arg)
        return traced

    @staticmethod
    def _is_jit_decorator(dec: ast.AST) -> bool:
        d = _dotted(dec)
        if d in ("jit", "jax.jit", "pjit", "jax.pjit"):
            return True
        if isinstance(dec, ast.Call):
            d = _dotted(dec.func)
            if d in ("jit", "jax.jit", "pjit", "jax.pjit"):
                return True
            if d and d.rsplit(".", 1)[-1] == "partial" and dec.args:
                inner = _dotted(dec.args[0])
                return inner in ("jit", "jax.jit", "pjit", "jax.pjit")
        return False

    # -- main traversal ----------------------------------------------------

    def run(self) -> list[Finding]:
        if any(_SKIP_FILE_RE.search(ln) for ln in self.lines[:10]):
            return []
        self._visit_block(self.tree.body, traced=False)
        self._check_timing_scope(self.tree.body)
        self.findings.sort(key=lambda f: (f.line, f.col, f.rule))
        return self.findings

    def _visit_block(self, body, *, traced: bool) -> None:
        env: dict[str, Optional[str]] = {}
        stack = list(body)
        # breadth-ish walk that tracks traced-ness across nested defs and
        # builds the flavor environment from assignments in source order
        nodes = []
        while stack:
            node = stack.pop(0)
            # defs/lambdas — wherever they appear — get their own region,
            # with traced-ness propagated (a def nested in a jitted body
            # is traced too)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                sub_traced = traced or id(node) in self.traced
                self._visit_block(node.body, traced=sub_traced)
                self._check_timing_scope(node.body)
                continue
            if isinstance(node, ast.Lambda):
                sub_traced = traced or id(node) in self.traced
                self._visit_expr_region([node.body], traced=sub_traced,
                                        env={})
                continue
            nodes.append(node)
            stack.extend(ast.iter_child_nodes(node))
        nodes.sort(key=lambda n: (getattr(n, "lineno", 0),
                                  getattr(n, "col_offset", 0)))
        for node in nodes:
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                env[node.targets[0].id] = _flavor(node.value, env)
            self._check_node(node, traced=traced, env=env)

    def _visit_expr_region(self, exprs, *, traced: bool, env: dict) -> None:
        for e in exprs:
            for node in ast.walk(e):
                self._check_node(node, traced=traced, env=env)

    def _check_node(self, node, *, traced: bool, env: dict) -> None:
        if isinstance(node, ast.Call):
            self._check_call(node, traced=traced, env=env)
        elif isinstance(node, ast.ExceptHandler):
            self._check_except(node)
        elif isinstance(node, (ast.If, ast.While)) and traced:
            if _is_arrayish(node.test):
                kw = "if" if isinstance(node, ast.If) else "while"
                self._flag(node, "RL002",
                           f"Python `{kw}` over an array expression in a "
                           f"traced region — use jnp.where / lax.cond")
        elif isinstance(node, ast.For) and traced:
            if _is_arrayish(node.iter):
                self._flag(node, "RL002",
                           "Python `for` over an array iterable in a "
                           "traced region — use lax.fori_loop / scan")

    def _check_call(self, node: ast.Call, *, traced: bool,
                    env: dict) -> None:
        d = _dotted(node.func)

        # RL001 — host syncs in traced regions
        if traced:
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("item", "tolist")
                    and not node.args):
                self._flag(node, "RL001",
                           f"`.{node.func.attr}()` forces a host sync "
                           f"inside a traced region")
            elif (isinstance(node.func, ast.Name)
                  and node.func.id in ("int", "bool", "float")
                  and len(node.args) == 1
                  and _is_arrayish(node.args[0])):
                self._flag(node, "RL001",
                           f"`{node.func.id}(...)` over an array "
                           f"expression concretizes a tracer (host sync)")
            elif d in _NP_CAST and node.args and not isinstance(
                    node.args[0], (ast.List, ast.Tuple, ast.Constant)):
                self._flag(node, "RL001",
                           f"`{d}` of a device value inside a traced "
                           f"region forces a transfer — use jnp")

        # RL003 — unpinned integer accumulation
        if d in _ACCUM_FNS and node.args:
            has_dtype = any(kw.arg == "dtype" for kw in node.keywords)
            parent = getattr(node, "_rl_parent", None)
            repinned = (isinstance(parent, ast.Attribute)
                        and parent.attr == "astype")
            if (not has_dtype and not repinned
                    and _flavor(node.args[0], env) in ("int", "bool")):
                self._flag(node, "RL003",
                           f"`{d}` over an integer/bool operand without "
                           f"dtype= promotes to int64 under "
                           f"jax_enable_x64 — pin dtype=jnp.int32")

        # RL005 — bare diagnostics in library code
        if self.lib:
            if isinstance(node.func, ast.Name) and node.func.id == "print":
                self._flag(node, "RL005",
                           "bare print() in library code — route through "
                           "repro.obs.log.get_logger(...)")
            elif d in ("warnings.warn",):
                self._flag(node, "RL005",
                           "warnings.warn() in library code — route "
                           "through repro.obs.log (deprecated()/logger)")

    # -- RL006: swallowed exceptions --------------------------------------

    @staticmethod
    def _broad_types(handler: ast.ExceptHandler):
        """Names among Exception/BaseException the handler catches."""
        nodes = (handler.type.elts if isinstance(handler.type, ast.Tuple)
                 else [handler.type])
        out = []
        for t in nodes:
            d = _dotted(t)
            leaf = d.rsplit(".", 1)[-1] if d else None
            if leaf in ("Exception", "BaseException"):
                out.append(leaf)
        return out

    def _check_except(self, handler: ast.ExceptHandler) -> None:
        body_raises = any(isinstance(n, ast.Raise)
                          for stmt in handler.body
                          for n in ast.walk(stmt))
        if handler.type is None:
            # a bare except: catches KeyboardInterrupt/SystemExit too —
            # only a re-raising cleanup handler gets a pass
            if not body_raises:
                self._flag(handler, "RL006",
                           "bare `except:` swallows every exception "
                           "(including KeyboardInterrupt) — catch a "
                           "concrete type, re-raise, or declare the "
                           "boundary with a disable comment")
            return
        broad = self._broad_types(handler)
        if not broad or body_raises:
            return
        trivial = all(
            isinstance(stmt, (ast.Pass, ast.Continue))
            or (isinstance(stmt, ast.Expr)
                and isinstance(stmt.value, ast.Constant)
                and stmt.value.value is Ellipsis)
            for stmt in handler.body)
        if trivial:
            self._flag(handler, "RL006",
                       f"`except {broad[0]}` with an empty body discards "
                       f"the failure — handle it, narrow the type, or "
                       f"declare the retry boundary with a disable "
                       f"comment")

    # -- RL004: per-scope timing analysis ---------------------------------

    def _check_timing_scope(self, body) -> None:
        timing_calls = []
        timing_subs = []
        fence_lines = []
        for node in _scope_nodes(body):
            if isinstance(node, ast.Call):
                d = _dotted(node.func)
                if d in _TIMING_FNS:
                    timing_calls.append(node)
                elif d and d.rsplit(".", 1)[-1] in _FENCE_CALLS:
                    fence_lines.append(node.lineno)
            if (isinstance(node, ast.Attribute)
                    and node.attr == _FENCE_ATTR):
                fence_lines.append(node.lineno)
            if isinstance(node, ast.Name) and node.id == _FENCE_ATTR:
                fence_lines.append(node.lineno)
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub):
                if any(isinstance(s, ast.Call)
                       and _dotted(s.func) in _TIMING_FNS
                       for s in ast.walk(node)):
                    timing_subs.append(node)
        measuring = len(timing_calls) >= 2 or timing_subs
        if not (measuring and timing_calls):
            return
        region = [n.lineno for n in timing_calls]
        region += [n.lineno for n in timing_subs]
        lo, hi = min(region), max(region)
        if any(lo <= ln <= hi for ln in fence_lines):
            return
        first = min(timing_calls, key=lambda n: n.lineno)
        self._flag(first, "RL004",
                   "timing region has no block_until_ready / span / "
                   "timed fence — async dispatch makes this measure "
                   "enqueue, not execution")


# --- public API ------------------------------------------------------------


def lint_source(source: str, path: str = "<string>", *,
                lib: Optional[bool] = None,
                select: Optional[set] = None,
                lib_root: str = "src/repro") -> list[Finding]:
    """Lint a source string. ``lib`` controls RL005 (library-only rule);
    when None it is inferred from ``path`` containing ``lib_root``."""
    if lib is None:
        lib = lib_root in Path(path).as_posix()
    try:
        return _FileLinter(path, source, lib=lib, select=select).run()
    except SyntaxError as e:
        return [Finding(path, e.lineno or 1, e.offset or 0, "RL000",
                        f"syntax error: {e.msg}")]


def lint_file(path, *, select: Optional[set] = None,
              lib_root: str = "src/repro") -> list[Finding]:
    p = Path(path)
    return lint_source(p.read_text(), str(p), select=select,
                       lib_root=lib_root)


def iter_py_files(paths) -> Iterable[Path]:
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            yield from sorted(q for q in p.rglob("*.py")
                              if "__pycache__" not in q.parts)
        elif p.suffix == ".py":
            yield p


def lint_paths(paths, *, select: Optional[set] = None,
               lib_root: str = "src/repro") -> list[Finding]:
    findings: list[Finding] = []
    for f in iter_py_files(paths):
        findings.extend(lint_file(f, select=select, lib_root=lib_root))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="reprolint — jit-discipline linter for the repro tree")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files/directories to lint (default: src/repro)")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids (default: all)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as a JSON array")
    ap.add_argument("--statistics", action="store_true",
                    help="print a per-rule count summary")
    ap.add_argument("--lib-root", default="src/repro",
                    help="path fragment marking library code for RL005")
    args = ap.parse_args(argv)

    select = ({s.strip().upper() for s in args.select.split(",")}
              if args.select else None)
    findings = lint_paths(args.paths, select=select,
                          lib_root=args.lib_root)
    if args.as_json:
        print(json.dumps([asdict(f) for f in findings], indent=1))  # reprolint: disable=RL005 -- CLI output channel
    else:
        for f in findings:
            print(f.render())  # reprolint: disable=RL005 -- CLI output channel
    if args.statistics:
        counts: dict[str, int] = {}
        for f in findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        for rule in sorted(counts):
            print(f"{rule}: {counts[rule]:4d}  {RULES.get(rule, '')}")  # reprolint: disable=RL005 -- CLI output channel
        nfiles = len(list(iter_py_files(args.paths)))
        print(f"{len(findings)} finding(s) across {nfiles} file(s)")  # reprolint: disable=RL005 -- CLI output channel
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
