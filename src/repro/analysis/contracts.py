"""Registry contract checker: static audit of the provider matrix.

``core.backend`` routes every operator hot path through a
(op × backend × placement × encoding) registry. The dispatch rules are
load-bearing — distributed placements never silently drop to single,
encoding-restricted providers must declare what they decode, every
primitive exposes ``telemetry=`` — but nothing re-verifies them once
the decorators have run. This module loads every provider module the
registry pulls lazily and checks the assembled matrix:

  CT001  distributed coverage: every op with a "sharded" provider has a
         "2d" provider and vice versa, OR the hole is a declared
         fallback (``backend.declare_fallback``). An undeclared hole is
         a provider someone forgot, not a design decision.
  CT002  encodings declared: every registered key has an encodings
         entry, the entry is a non-empty subset of {dense, delta}, and
         contains "dense" (the universal contract every provider must
         accept after the registry-level decode fallback).
  CT003  telemetry surface: each of the six paper primitives exposes a
         ``telemetry=`` keyword.
  CT004  no silent fallback to single: a distributed dispatch with no
         provider raises ``ProviderMissError``, and no distributed key
         shares its callable with the op's single-placement key (which
         would be a fallback wearing a registration).
  CT005  xla twin: every pallas provider has an xla provider under the
         same placement — the pallas→xla fallback the dispatch rules
         promise must have somewhere to land.
  CT006  compile budgets: each of the six primitives has a declared
         retrace budget (``analysis.budgets.COMPILE_BUDGETS``).

Run as a test (``tests/test_analysis.py``) and a CLI:
``python -m repro.analysis.contracts`` (exit 1 on findings).
"""
from __future__ import annotations

import importlib
import inspect
from dataclasses import dataclass
from typing import List

# The six paper primitives: registry name -> (module, public callable).
PRIMITIVES = {
    "bfs": ("repro.core.primitives.bfs", "bfs"),
    "sssp": ("repro.core.primitives.sssp", "sssp"),
    "pagerank": ("repro.core.primitives.pagerank", "pagerank"),
    "cc": ("repro.core.primitives.cc", "connected_components"),
    "bc": ("repro.core.primitives.bc", "bc"),
    "tc": ("repro.core.primitives.tc", "triangle_count"),
}

# Every module that registers providers on import — the registry is
# lazy, so the checker must pull them all in before reading the matrix.
PROVIDER_MODULES = (
    "repro.core.operators",
    "repro.core.frontier",
    "repro.linalg.ops",
    "repro.kernels.ops",
    "repro.core.distributed",
)

VALID_ENCODINGS = frozenset({"dense", "delta"})


@dataclass(frozen=True)
class ContractFinding:
    rule: str
    key: str      # "op/backend/placement" or "op"
    message: str

    def render(self) -> str:
        return f"{self.rule} [{self.key}] {self.message}"


def _load_registry():
    for mod in PROVIDER_MODULES:
        importlib.import_module(mod)
    from repro.core import backend as B
    return B


def check_registry() -> List[ContractFinding]:
    """Audit the fully-loaded provider matrix; returns all findings."""
    B = _load_registry()
    reg = dict(B._REGISTRY)
    enc = dict(B._ENCODINGS)
    findings: List[ContractFinding] = []

    ops = sorted({k[0] for k in reg})
    by_placement = {pl: {k[0] for k in reg if k[2] == pl}
                    for pl in B.PLACEMENTS}

    # CT001 — sharded <-> 2d coverage, honouring declared fallbacks
    for a, b in ((B.SHARDED, B.TWOD), (B.TWOD, B.SHARDED)):
        for op in sorted(by_placement[a] - by_placement[b]):
            if B.declared_fallback(op, b) is None:
                findings.append(ContractFinding(
                    "CT001", f"{op}/{b}",
                    f"op has a {a!r} provider but no {b!r} provider and "
                    f"no declared fallback — register one or "
                    f"declare_fallback({op!r}, {b!r}, reason=...)"))

    # CT002 — encodings declared and valid for every registered key
    for key in sorted(reg):
        kid = "/".join(key)
        declared = enc.get(key)
        if declared is None:
            findings.append(ContractFinding(
                "CT002", kid, "registered provider has no encodings "
                "entry (register() must record one)"))
            continue
        bad = set(declared) - VALID_ENCODINGS
        if bad:
            findings.append(ContractFinding(
                "CT002", kid, f"unknown encodings declared: {sorted(bad)}"))
        if "dense" not in declared:
            findings.append(ContractFinding(
                "CT002", kid, "provider does not declare 'dense' — every "
                "provider must accept the decode-to-dense fallback"))

    # CT003 — telemetry= on every primitive's public wrapper
    for name, (mod, fn_name) in PRIMITIVES.items():
        fn = getattr(importlib.import_module(mod), fn_name)
        params = inspect.signature(fn).parameters
        if "telemetry" not in params:
            findings.append(ContractFinding(
                "CT003", name,
                f"{mod}.{fn_name} does not expose a telemetry= keyword"))

    # CT004 — no silent fallback to single.
    # (a) behavioural: a distributed miss must raise ProviderMissError
    probe = [op for op in ops if op not in by_placement[B.SHARDED]]
    for op in probe[:1]:
        try:
            B.dispatch(op, B.XLA, B.SHARDED)
        except B.ProviderMissError:
            pass
        except KeyError:
            findings.append(ContractFinding(
                "CT004", f"{op}/xla/sharded",
                "distributed miss raised a bare KeyError, not "
                "ProviderMissError — the structured miss contract"))
        else:
            findings.append(ContractFinding(
                "CT004", f"{op}/xla/sharded",
                "distributed dispatch with no provider returned an "
                "implementation — a silent fallback to single"))
    # (b) structural: no distributed key aliases the single callable
    for (op, bk, pl), fn in sorted(reg.items()):
        if pl == B.SINGLE:
            continue
        single = reg.get((op, bk, B.SINGLE)) or reg.get((op, B.XLA, B.SINGLE))
        if single is not None and fn is single:
            findings.append(ContractFinding(
                "CT004", f"{op}/{bk}/{pl}",
                "distributed registration reuses the single-placement "
                "callable — a silent single fallback wearing a "
                "registration"))

    # CT005 — every pallas provider has an xla twin (fallback target)
    for (op, bk, pl) in sorted(reg):
        if bk == B.PALLAS and (op, B.XLA, pl) not in reg:
            findings.append(ContractFinding(
                "CT005", f"{op}/pallas/{pl}",
                f"pallas provider has no xla twin under {pl!r}; the "
                f"pallas→xla fallback has nowhere to land"))

    # CT006 — compile budget declared for each primitive
    from .budgets import COMPILE_BUDGETS
    for name in PRIMITIVES:
        if name not in COMPILE_BUDGETS:
            findings.append(ContractFinding(
                "CT006", name,
                "primitive has no declared compile budget in "
                "repro.analysis.budgets.COMPILE_BUDGETS"))

    return findings


def matrix() -> str:
    """Human-readable provider matrix: one row per op, one column per
    (backend, placement) pair, encodings annotated."""
    B = _load_registry()
    reg = B._REGISTRY
    enc = B._ENCODINGS
    cols = [(bk, pl) for pl in B.PLACEMENTS for bk in (B.XLA, B.PALLAS)]
    ops = sorted({k[0] for k in reg})
    head = ["op"] + [f"{bk}/{pl}" for bk, pl in cols]
    rows = [head]
    for op in ops:
        row = [op]
        for bk, pl in cols:
            key = (op, bk, pl)
            if key in reg:
                e = enc.get(key, ())
                row.append("+delta" if "delta" in e else "yes")
            elif B.declared_fallback(op, pl) is not None:
                row.append("(declared)")
            else:
                row.append("-")
        rows.append(row)
    widths = [max(len(r[i]) for r in rows) for i in range(len(head))]
    return "\n".join(
        "  ".join(c.ljust(w) for c, w in zip(r, widths)) for r in rows)


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.contracts",
        description="Check the backend registry's provider-matrix "
                    "contracts (CT001-CT006).")
    p.add_argument("--matrix", action="store_true",
                   help="print the provider matrix and exit")
    ns = p.parse_args(argv)
    if ns.matrix:
        print(matrix())                      # reprolint: disable=RL005 -- CLI output channel
        return 0
    findings = check_registry()
    for f in findings:
        print(f.render())                    # reprolint: disable=RL005 -- CLI output channel
    print(f"{len(findings)} contract finding(s)")  # reprolint: disable=RL005 -- CLI output channel
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
