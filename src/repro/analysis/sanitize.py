"""Runtime sanitizers: retrace detection and Pallas grid memory checks.

Both detectors exploit the same fact: a jitted function's *Python body*
runs only when JAX traces it, so pure-Python side effects placed there
are exact compile counters, and every shape/grid/BlockSpec a kernel
wrapper builds is concrete at trace time — checkable without executing
the kernel and without breaking jit (nothing here touches tracer
values).

Retrace detector
    Each of the six primitives calls ``trace_probe("<name>")`` at the
    top of its jitted impl. The counter increments once per jit cache
    miss — a retrace-per-call bug (the serving-path recompile killer)
    shows up as a counter that tracks the call count.
    ``retrace_guard(name)`` wraps a hot loop and raises
    ``RetraceError`` when the window's fresh traces exceed the
    primitive's declared budget (``budgets.COMPILE_BUDGETS``).

Pallas memory sanitizer
    ``kernels.runtime.pallas_call`` routes every kernel's grid +
    BlockSpecs through ``check_pallas_spec`` when sanitizing is on
    (``REPRO_SANITIZE=1`` or the ``sanitizing()`` context). For every
    grid cell it evaluates each operand's ``index_map`` and verifies
    (1) the mapped block lies inside the operand — an out-of-bounds
    tile load/store corrupts neighbours silently in interpret mode and
    faults unpredictably compiled; (2) no two grid cells map the same
    OUTPUT block unless the wrapper declared that output an accumulator
    (the sequential-grid accumulation pattern, e.g. the
    ``advance_filter`` bitmap) — an undeclared revisit is a write-write
    race on any platform with a parallel grid dimension.

Scope/limits: the checker sees block-granularity addressing only —
element-level indexing bugs *inside* a kernel body (a bad ``pl.load``
index) are out of scope, as is cross-operand aliasing. Grids larger
than ``MAX_CELLS`` are sampled (all boundary cells plus a stride
through the interior), so a race between two interior cells of a huge
grid can in principle be missed; every grid this codebase launches at
test sizes enumerates fully.

This module is stdlib-only so ``repro.core`` / ``repro.kernels`` can
import it without cycles.
"""
from __future__ import annotations

import itertools
import math
import os
import threading
from collections import Counter
from contextlib import contextmanager
from typing import Optional, Sequence

ENV_VAR = "REPRO_SANITIZE"

_tls = threading.local()

# ---------------------------------------------------------------------------
# activation
# ---------------------------------------------------------------------------


def _ctx_stack() -> list:
    if not hasattr(_tls, "stack"):
        _tls.stack = []
    return _tls.stack


def enabled() -> bool:
    """Sanitizing active? Innermost ``sanitizing()`` context wins, else
    the ``REPRO_SANITIZE`` env var (any value but ''/'0'/'false')."""
    stack = _ctx_stack()
    if stack:
        return stack[-1]
    return os.environ.get(ENV_VAR, "") not in ("", "0", "false", "False")


@contextmanager
def sanitizing(on: bool = True):
    """Context manager: force sanitizing on (or off) for the block.
    Resolution happens at kernel *trace* time, so already-cached traces
    are not re-checked — use fresh shapes (or explicit ``interpret=``)
    when asserting on the checks in tests."""
    _ctx_stack().append(bool(on))
    try:
        yield
    finally:
        _ctx_stack().pop()


# ---------------------------------------------------------------------------
# retrace detector
# ---------------------------------------------------------------------------

_TRACE_COUNTS: Counter = Counter()


class RetraceError(RuntimeError):
    """A primitive exceeded its declared compile budget inside a
    ``retrace_guard`` window."""


def trace_probe(name: str) -> None:
    """Count one trace of ``name``. Call this from INSIDE a jitted
    function body: the Python body only runs on a jit cache miss, so
    the count is exactly the compile count. Costs nothing at runtime —
    the compiled program never sees it."""
    _TRACE_COUNTS[name] += 1


def trace_count(name: str) -> int:
    """Total traces recorded for ``name`` in this process."""
    return _TRACE_COUNTS[name]


@contextmanager
def retrace_guard(name: str, budget: Optional[int] = None,
                  enforce: bool = True):
    """Fail a hot loop that recompiles: raises ``RetraceError`` when the
    block traces ``name`` more than ``budget`` times (default: the
    primitive's declared ``budgets.COMPILE_BUDGETS`` entry).

    Yields a report dict; ``report["traces"]`` is filled at exit so
    callers can log the window even when it passes. ``enforce=False``
    records without raising (the observability mode).
    """
    if budget is None:
        from .budgets import budget_for
        budget = budget_for(name)
    start = _TRACE_COUNTS[name]
    report = {"name": name, "budget": budget, "traces": None}
    try:
        yield report
    finally:
        report["traces"] = _TRACE_COUNTS[name] - start
    if enforce and report["traces"] > budget:
        raise RetraceError(
            f"primitive {name!r} traced {report['traces']}× in a guarded "
            f"window (budget {budget}): a fixed workload config is "
            f"recompiling per call — check for unhashed static args, "
            f"Python branches on call data, or shape churn in the caller")


# ---------------------------------------------------------------------------
# pallas grid/BlockSpec memory sanitizer
# ---------------------------------------------------------------------------

MAX_CELLS = 4096


class MemoryFault(RuntimeError):
    """An out-of-bounds tile map or an undeclared write-write race."""


def _cells(grid: Sequence[int]):
    """Grid cells to check: the full product when small enough, else
    every boundary cell plus an interior stride (sampled grids can in
    principle miss an interior-only fault; see module docstring)."""
    grid = tuple(int(g) for g in grid)
    total = math.prod(grid) if grid else 1
    if total <= MAX_CELLS:
        yield from itertools.product(*(range(g) for g in grid))
        return
    seen = set()
    # all cells touching any face of the grid box
    for d in range(len(grid)):
        for edge in (0, grid[d] - 1):
            axes = [range(g) if i != d else (edge,)
                    for i, g in enumerate(grid)]
            budget = MAX_CELLS // (2 * len(grid))
            for cell in itertools.islice(itertools.product(*axes), budget):
                if cell not in seen:
                    seen.add(cell)
                    yield cell
    # a deterministic stride through the flat interior
    stride = max(total // MAX_CELLS, 1)
    for flat in range(0, total, stride):
        cell = []
        rem = flat
        for g in reversed(grid):
            cell.append(rem % g)
            rem //= g
        cell = tuple(reversed(cell))
        if cell not in seen:
            seen.add(cell)
            yield cell


def _block_index(spec, cell, *, name: str, operand: str):
    idx = spec.index_map(*cell)
    if not isinstance(idx, tuple):
        idx = (idx,)
    return tuple(int(i) for i in idx)


def check_pallas_spec(*, name: str, grid, in_specs, out_specs,
                      in_shapes, out_shapes,
                      accumulate: Sequence[int] = ()) -> None:
    """Trace-time audit of one ``pallas_call``'s tile addressing.

    ``accumulate`` lists OUTPUT positions whose blocks are legitimately
    revisited across (sequential) grid steps — the accumulation
    pattern; any other output block mapped by two different cells is a
    write-write race and faults.
    """
    grid = (grid,) if isinstance(grid, int) else tuple(grid)
    accumulate = set(accumulate)
    operands = (
        [("in", i, spec, shape)
         for i, (spec, shape) in enumerate(zip(in_specs, in_shapes))]
        + [("out", i, spec, shape)
           for i, (spec, shape) in enumerate(zip(out_specs, out_shapes))])

    checked = []
    for kind, i, spec, shape in operands:
        block = tuple(int(b) for b in spec.block_shape)
        shape = tuple(int(s) for s in shape)
        opname = f"{kind}[{i}]"
        if len(block) != len(shape):
            raise MemoryFault(
                f"{name}: {opname} block rank {len(block)} != operand "
                f"rank {len(shape)} (block {block}, shape {shape})")
        nblocks = tuple(-(-s // b) for s, b in zip(shape, block))
        checked.append((kind, i, spec, block, shape, nblocks, opname))

    writes: dict[int, dict] = {}
    for cell in _cells(grid):
        for kind, i, spec, block, shape, nblocks, opname in checked:
            idx = _block_index(spec, cell, name=name, operand=opname)
            if len(idx) != len(shape):
                raise MemoryFault(
                    f"{name}: {opname} index_map{cell} returned rank "
                    f"{len(idx)}, operand rank is {len(shape)}")
            for d, (b_idx, nb) in enumerate(zip(idx, nblocks)):
                if not 0 <= b_idx < nb:
                    lo = b_idx * block[d]
                    raise MemoryFault(
                        f"{name}: out-of-bounds tile on {opname} at grid "
                        f"cell {cell}: index_map -> block {idx}, but dim "
                        f"{d} has {nb} block(s) of {block[d]} over extent "
                        f"{shape[d]} (elements [{lo}, {lo + block[d]}) "
                        f"are outside the operand)")
            if kind == "out" and i not in accumulate:
                prev = writes.setdefault(i, {}).get(idx)
                if prev is not None and prev != cell:
                    raise MemoryFault(
                        f"{name}: write-write race on out[{i}]: grid "
                        f"cells {prev} and {cell} both map output block "
                        f"{idx}; declare the output an accumulator "
                        f"(accumulate=) if the revisit is the sequential "
                        f"accumulation pattern")
                writes.setdefault(i, {})[idx] = cell
