"""Sharding vocabulary and helpers.

The framework uses a fixed logical-axis vocabulary:
  "pod"   — inter-pod data parallelism (DCN-crossing; gradients only)
  "data"  — intra-pod data parallelism + FSDP (ZeRO-3) param sharding
  "model" — tensor parallelism (attention heads / FFN hidden / experts /
            vocab)

Model code writes PartitionSpecs in this vocabulary; `spec_for_mesh`
projects a spec onto whatever mesh is active (axes absent from the mesh
are dropped), so the same model runs on a single device, a 16×16 pod, or
the 2×16×16 multi-pod mesh unchanged.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.jax_compat import get_abstract_mesh

BATCH_AXES = ("pod", "data")      # batch dim shards over both when present
FSDP_AXIS = "data"
TENSOR_AXIS = "model"
POD_AXIS = "pod"


def _filter_entry(entry, axis_names):
    if entry is None:
        return None
    if isinstance(entry, str):
        return entry if entry in axis_names else None
    # tuple of axes: keep the present ones
    kept = tuple(a for a in entry if a in axis_names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def spec_for_mesh(spec: P, mesh=None) -> P:
    """Drop axes not present in ``mesh`` (or the active abstract mesh)."""
    if mesh is None:
        mesh = get_abstract_mesh()
        if mesh is None or mesh.empty:
            return P()
    names = mesh.axis_names
    return P(*[_filter_entry(e, names) for e in spec])


def mesh_axis_size(name: str) -> int:
    """Size of a mesh axis in the active abstract mesh (1 if absent)."""
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    return sizes.get(name, 1)


def constrain(x, *spec_entries):
    """with_sharding_constraint against the active mesh; no-op when no mesh
    is active (single-device tests) or in eager mode."""
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = spec_for_mesh(P(*spec_entries), mesh)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, TypeError):
        return x


def make_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec_for_mesh(spec, mesh))


def fit_sharding(mesh, shape, spec: P) -> NamedSharding:
    """NamedSharding with axes dropped wherever the dim isn't divisible by
    the mesh-axis product (e.g. batch=1 long-context cells, odd block
    counts of quantized optimizer moments)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = spec_for_mesh(spec, mesh)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept, prod = [], 1
        for a in axes:
            if dim % (prod * sizes[a]) == 0:
                kept.append(a)
                prod *= sizes[a]
        out.append(tuple(kept) if len(kept) > 1
                   else (kept[0] if kept else None))
    return NamedSharding(mesh, P(*out))


def tree_shardings(mesh: Mesh, spec_tree):
    """Map a pytree of PartitionSpecs to NamedShardings on ``mesh``."""
    return jax.tree.map(
        lambda s: make_sharding(mesh, s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P))
