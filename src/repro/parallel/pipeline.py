"""GPipe-style pipeline parallelism over a mesh axis.

`pipeline_apply` runs `stage_fn` across S stages (devices along the
"stage" axis) on M microbatches with the classic (M + S − 1)-tick
schedule: on every tick each stage processes the microbatch it holds and
`ppermute`s its activations to the next stage — compute and the
stage-to-stage transfer overlap across ticks, which is the
distributed-optimization trick PP brings (bubble fraction (S−1)/(M+S−1)).

Each device holds only its own stage's parameters (the stacked stage
params are sharded over the axis), so PP composes with DP/TP on the other
mesh axes. The dry-run meshes use DP×TP; PP is exercised by
tests/test_pipeline.py and examples/pipeline_mlp.py, and is available to
the launcher via --pipeline-stages.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(stage_fn: Callable, stage_params, x, mesh: Mesh,
                   n_microbatches: int, axis: str = "stage"):
    """Run microbatched pipeline-parallel forward.

    stage_fn(params_for_stage, x_micro) -> y_micro (same shape).
    stage_params: pytree with leading axis = n_stages.
    x: (global_batch, ...) — split into n_microbatches on axis 0.
    Returns y with x's shape.
    """
    n_stages = mesh.shape[axis]
    gb = x.shape[0]
    assert gb % n_microbatches == 0
    mb = gb // n_microbatches
    xs = x.reshape((n_microbatches, mb) + x.shape[1:])

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False)
    def run(params_s, xs_rep):
        my_params = jax.tree.map(lambda a: a[0], params_s)
        stage = jax.lax.axis_index(axis)
        n_ticks = n_microbatches + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        buf = jnp.zeros((mb,) + xs_rep.shape[2:], xs_rep.dtype)
        outs = jnp.zeros_like(xs_rep)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if available)
            feed = xs_rep[jnp.clip(t, 0, n_microbatches - 1)]
            buf = jnp.where(stage == 0,
                            jnp.where(t < n_microbatches, feed, buf), buf)
            y = stage_fn(my_params, buf)
            # last stage retires microbatch t-(S-1)
            done_idx = t - (n_stages - 1)
            write = (stage == n_stages - 1) & (done_idx >= 0)
            outs = jax.lax.cond(
                write,
                lambda o: jax.lax.dynamic_update_slice(
                    o, y[None], (jnp.maximum(done_idx, 0), 0)
                    + (0,) * (y.ndim - 1)),
                lambda o: o, outs)
            # hand activations to the next stage
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # only the last stage wrote non-zeros; psum broadcasts its results
        outs = jax.lax.psum(outs, axis)
        return outs

    ys = run(stage_params, xs)
    return ys.reshape((gb,) + x.shape[1:])
