from .sharding import constrain, make_sharding, spec_for_mesh

__all__ = ["constrain", "make_sharding", "spec_for_mesh"]
