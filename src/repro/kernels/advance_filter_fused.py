"""Pallas megakernel: fused advance + filter (paper §5.3 taken whole).

The unfused traversal step materializes the full ``(cap_out,)`` edge
six-tuple in HBM between two registry ops: advance expands and gathers,
then filter re-reads everything to test the visited bitmap, uniquify and
compact. Gunrock's kernel-fusion strategy (and GraphBLAST's fused masked
operations) put the functor, the status test and the compaction inside
the expansion kernel; this is that kernel for the TPU engine. One
``pallas_call`` does

  LB sorted search → CSR gathers → visited-bitmap predicate →
  exact first-occurrence culling → compacted emission,

emitting only surviving destinations (+ their discovering sources) plus
a running survivor count — the intermediate edge tuple never exists.

The mechanism that makes in-kernel culling exact is the *sequential*
Pallas grid: tiles execute in order, and the working bitmap + output
buffers live in constant-index-map output blocks that persist across
grid steps (the standard accumulation pattern). A destination kept by
tile t marks the bitmap before tile t+1 tests it, so cross-tile
duplicates die in the predicate; in-tile duplicates die by a lane
comparison matrix (first occurrence in slot order wins, globally).

The XLA provider in ``core.operators`` composes the unfused path to the
same contract (predicate → min-lane winner scatter → compaction), so
every parity test has an oracle: fused == composed, bit for bit,
including the emission ORDER (first-occurrence positions are ascending
in slot order — exactly compaction order).

``advance_filter_fused_batch_kernel`` is the multi-source variant on the
(B, tiles) grid of ``advance_fused``: per-lane prefix sums, bitmaps and
output rows selected by the batch coordinate, CSR broadcast. Grid
iteration is row-major, so each lane's tiles stay sequential — the
per-lane bitmap discipline is untouched.

Scatter/gather note: emissions use value-level ``.at[]`` updates on the
VMEM-resident output block (dynamic-index stores, the accumulate
pattern); interpret mode — the off-TPU correctness contract — executes
them as jnp scatters.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import runtime, tuner
from .advance_fused import _lb_body, _split_store


def _step(offsets, base, row_offsets, col_indices, vis, bm_prev, ids_prev,
          src_prev, cnt_prev, first, slots, *, cap_in: int, num_edges: int,
          n: int, iters: int, cap_front: int, anchor=None):
    """One tile's worth of fused work on value-level state. Shared by the
    single-lane and batched kernels (they differ only in ref slicing)."""
    tile = slots.shape[0]
    bm = jnp.where(first, vis, bm_prev)
    cnt = jnp.where(first, 0, cnt_prev)
    out_ids = jnp.where(first, jnp.full((cap_front,), -1, jnp.int32),
                        ids_prev)
    out_src = jnp.where(first, jnp.full((cap_front,), -1, jnp.int32),
                        src_prev)

    src, dst, _, _, _, valid = _lb_body(
        offsets, base, row_offsets, col_indices, slots,
        cap_in=cap_in, num_edges=num_edges, iters=iters, anchor=anchor)
    valid = valid > 0
    safe_dst = jnp.where(valid, dst, 0)

    # functor predicate + visited test (idempotent discovery, §5.2.1)
    keep = valid & (bm[safe_dst] == 0)
    # in-tile first-occurrence culling: lane i dies if an earlier kept
    # lane claims the same destination (cross-tile dups already died on
    # the bitmap test above)
    lane = jax.lax.iota(jnp.int32, tile)
    earlier_same = ((safe_dst[None, :] == safe_dst[:, None])
                    & keep[None, :] & (lane[None, :] < lane[:, None]))
    keep = keep & ~jnp.any(earlier_same, axis=1)

    bm = bm.at[safe_dst].max(keep.astype(jnp.int32))

    kept = keep.astype(jnp.int32)
    gpos = cnt + jnp.cumsum(kept, dtype=jnp.int32) - kept
    tgt = jnp.where(keep & (gpos < cap_front), gpos, cap_front)
    out_ids = out_ids.at[tgt].set(dst, mode="drop")
    out_src = out_src.at[tgt].set(src, mode="drop")
    # dtype= pins the count under jax_enable_x64 (int32 sums otherwise
    # promote to int64 and poison the carried cnt / output ref)
    cnt = cnt + jnp.sum(kept, dtype=jnp.int32)
    return bm, out_ids, out_src, cnt


def _kernel(offsets_ref, base_ref, ro_ref, ci_ref, anchor_ref, vis_ref,
            ids_ref, src_ref, cnt_ref, bm_ref, *,
            cap_in: int, num_edges: int, n: int, iters: int, tile: int,
            cap_front: int, encoded: bool):
    t = pl.program_id(0)
    slots = t * tile + jax.lax.iota(jnp.int32, tile)
    bm, out_ids, out_src, cnt = _step(
        offsets_ref[...], base_ref[...], ro_ref[...], ci_ref[...],
        vis_ref[...], bm_ref[...], ids_ref[...], src_ref[...],
        cnt_ref[0], t == 0, slots, cap_in=cap_in, num_edges=num_edges,
        n=n, iters=iters, cap_front=cap_front,
        anchor=anchor_ref[...] if encoded else None)
    bm_ref[...] = bm
    ids_ref[...] = out_ids
    src_ref[...] = out_src
    cnt_ref[...] = jnp.full((1,), cnt, jnp.int32)


@functools.partial(jax.jit, static_argnames=("cap_out", "cap_front",
                                             "interpret", "tile"))
def advance_filter_fused_kernel(offsets: jax.Array, base: jax.Array,
                                row_offsets: jax.Array,
                                col_indices, visited: jax.Array,
                                cap_out: int, cap_front: int,
                                interpret: bool | None = None,
                                tile: int | None = None):
    """One-pass advance+filter.

    offsets:     (cap_in+1,) int32 exclusive prefix sum of masked degrees.
    base:        (cap_in,)   int32 base vertices (invalid lanes 0).
    row_offsets / col_indices: CSR (m ≥ 1); ``col_indices`` may be a
                 ``storage.EncodedCols`` delta stream, decoded in the
                 LB body (see ``advance_fused._lb_body``).
    visited:     (n,) int32 bitmap — destinations with a set bit are
                 culled; survivors set their bit for later slots.

    Returns (ids, srcs, length, total): ids/srcs (cap_front,) compacted
    surviving destinations + discovering sources (-1 padded, clamped at
    cap_front), length = min(total, cap_front), total = true survivor
    count. Matches the XLA advance→filter composition bit for bit.
    """
    interpret = runtime.interpret_mode(interpret)
    cap_in = offsets.shape[0] - 1
    ci, anchor, encoded = _split_store(col_indices)
    m = ci.shape[0]
    n = visited.shape[0]
    if tile is None:
        tile = tuner.tile_for("advance_filter", cap_out,
                              encoding="delta" if encoded else "dense")
    padded = -(-cap_out // tile) * tile
    iters = max(math.ceil(math.log2(max(cap_in, 2))) + 1, 1)
    grid = (padded // tile,)
    bcast = lambda shape: pl.BlockSpec(shape, lambda i: (0,))
    # every output block persists across the sequential grid (the
    # accumulation pattern the module docstring describes) — declared so
    # the memory sanitizer doesn't read the revisits as races
    ids, srcs, cnt, _ = runtime.pallas_call(
        functools.partial(_kernel, cap_in=cap_in, num_edges=m, n=n,
                          iters=iters, tile=tile, cap_front=cap_front,
                          encoded=encoded),
        name="advance_filter_fused",
        grid=grid,
        in_specs=[bcast((cap_in + 1,)), bcast((cap_in,)),
                  bcast(row_offsets.shape), bcast(ci.shape),
                  bcast(anchor.shape), bcast((n,))],
        out_specs=[bcast((cap_front,)), bcast((cap_front,)),
                   bcast((1,)), bcast((n,))],
        out_shape=[jax.ShapeDtypeStruct((cap_front,), jnp.int32),
                   jax.ShapeDtypeStruct((cap_front,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.int32)],
        interpret=interpret,
        accumulate=(0, 1, 2, 3),
    )(offsets, base, row_offsets, ci, anchor,
      visited.astype(jnp.int32))
    total = cnt[0]
    return ids, srcs, jnp.minimum(total, cap_front), total


def _batch_kernel(offsets_ref, base_ref, ro_ref, ci_ref, anchor_ref,
                  vis_ref, ids_ref, src_ref, cnt_ref, bm_ref, *,
                  cap_in: int, num_edges: int, n: int, iters: int,
                  tile: int, cap_front: int, encoded: bool):
    t = pl.program_id(1)
    slots = t * tile + jax.lax.iota(jnp.int32, tile)
    bm, out_ids, out_src, cnt = _step(
        offsets_ref[0, :], base_ref[0, :], ro_ref[0, :], ci_ref[0, :],
        vis_ref[0, :], bm_ref[0, :], ids_ref[0, :], src_ref[0, :],
        cnt_ref[0, 0], t == 0, slots, cap_in=cap_in, num_edges=num_edges,
        n=n, iters=iters, cap_front=cap_front,
        anchor=anchor_ref[0, :] if encoded else None)
    bm_ref[0, :] = bm
    ids_ref[0, :] = out_ids
    src_ref[0, :] = out_src
    cnt_ref[0, :] = jnp.full((1,), cnt, jnp.int32)


@functools.partial(jax.jit, static_argnames=("cap_out", "cap_front",
                                             "interpret", "tile"))
def advance_filter_fused_batch_kernel(offsets: jax.Array, base: jax.Array,
                                      row_offsets: jax.Array,
                                      col_indices,
                                      visited: jax.Array,
                                      cap_out: int, cap_front: int,
                                      interpret: bool | None = None,
                                      tile: int | None = None):
    """Multi-source fused advance+filter over a (B, tiles) grid.

    offsets (B, cap_in+1), base (B, cap_in), visited (B, n); CSR shared.
    Returns (ids, srcs, lengths, totals) with ids/srcs (B, cap_front)
    and lengths/totals (B,) — per-lane semantics identical to the
    single-lane kernel (grid iteration is row-major, so each lane's
    tiles run sequentially against its own bitmap row).
    """
    interpret = runtime.interpret_mode(interpret)
    b, cap_in1 = offsets.shape
    cap_in = cap_in1 - 1
    ci, anchor, encoded = _split_store(col_indices)
    m = ci.shape[0]
    n = visited.shape[1]
    if tile is None:
        tile = tuner.tile_for("advance_filter", cap_out, lanes=b,
                              encoding="delta" if encoded else "dense")
    padded = -(-cap_out // tile) * tile
    iters = max(math.ceil(math.log2(max(cap_in, 2))) + 1, 1)
    grid = (b, padded // tile)
    row = lambda shape: pl.BlockSpec((1,) + shape, lambda bi, ti: (bi, 0))
    bcast = lambda shape: pl.BlockSpec((1,) + shape, lambda bi, ti: (0, 0))
    # per-lane output rows persist across the (row-major sequential)
    # tile axis — the batched accumulation pattern; declared for the
    # memory sanitizer
    ids, srcs, cnt, _ = runtime.pallas_call(
        functools.partial(_batch_kernel, cap_in=cap_in, num_edges=m, n=n,
                          iters=iters, tile=tile, cap_front=cap_front,
                          encoded=encoded),
        name="advance_filter_fused_batch",
        grid=grid,
        in_specs=[row((cap_in + 1,)), row((cap_in,)),
                  bcast(row_offsets.shape), bcast(ci.shape),
                  bcast(anchor.shape), row((n,))],
        out_specs=[row((cap_front,)), row((cap_front,)),
                   row((1,)), row((n,))],
        out_shape=[jax.ShapeDtypeStruct((b, cap_front), jnp.int32),
                   jax.ShapeDtypeStruct((b, cap_front), jnp.int32),
                   jax.ShapeDtypeStruct((b, 1), jnp.int32),
                   jax.ShapeDtypeStruct((b, n), jnp.int32)],
        interpret=interpret,
        accumulate=(0, 1, 2, 3),
    )(offsets, base, row_offsets[None, :], ci[None, :], anchor[None, :],
      visited.astype(jnp.int32))
    totals = cnt[:, 0]
    return ids, srcs, jnp.minimum(totals, cap_front), totals
