"""Public kernel API — jit'd wrappers that dispatch to Pallas kernels.

On a TPU backend the kernels compile natively (interpret=False); on this
CPU container they run in interpret mode, which executes the kernel body
in Python and is the validation contract (tests compare every kernel
against the ref.py oracle across shape/dtype sweeps).

Every wrapper here registers itself as the ``"pallas"`` implementation of
its operator hot path in ``repro.core.backend``; the operator layer in
``repro.core`` dispatches through that registry instead of threading
``use_kernel`` booleans by hand. This module is imported lazily by the
registry on the first pallas dispatch.

All registrations here are single-placement: under the distributed
placements (``"sharded"``, ``"2d"``) a pallas selection falls back to
the placement's xla provider — within the placement, never across to a
single-device impl (Pallas kernels under shard_map are future work;
they would need per-shard/per-block ELL repacking, see DESIGN.md §6).

Set ``REPRO_FORCE_INTERPRET=0`` to attempt native compilation.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import backend as B
from repro.core import storage as St

from . import ref, tuner
from .advance_filter_fused import (advance_filter_fused_batch_kernel,
                                   advance_filter_fused_kernel)
from .advance_fused import advance_fused_batch_kernel, advance_fused_kernel
from .filter_compact import filter_compact_kernel
from .flash_attention import flash_attention_kernel
from .lb_expand import lb_expand_kernel
from .moe_dispatch import moe_gather_kernel
from .runtime import interpret_mode as _interpret
from .segment_search import segment_search_kernel
from .semiring_spmv import semiring_ell_kernel


class KExpansion(NamedTuple):
    in_pos: jax.Array
    rank: jax.Array
    valid: jax.Array
    total: jax.Array


def lb_expand(sizes: jax.Array, cap_out: int) -> KExpansion:
    """Kernel-backed LB expansion; drop-in for operators.lb_expand."""
    sizes = sizes.astype(jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(sizes, dtype=jnp.int32)])
    in_pos, rank, valid = lb_expand_kernel(offsets, cap_out,
                                           interpret=_interpret())
    return KExpansion(in_pos=in_pos, rank=rank, valid=valid > 0,
                      total=offsets[-1])


@B.register("advance", B.PALLAS, encodings=("dense", "delta"))
def advance_fused(row_offsets: jax.Array, col_indices,
                  base: jax.Array, sizes: jax.Array, cap_out: int):
    """Fused LB advance: one pallas_call does the sorted search over the
    degree prefix sum *and* the CSR gathers (paper §5.1.3 + the §5.3
    fusion philosophy). Returns (src, dst, edge_id, in_pos, rank, valid,
    total) — the backend-registry contract shared with the XLA
    implementation in ``core.operators``. ``col_indices`` may be dense
    (any int dtype) or a ``storage.EncodedCols`` delta stream — the
    kernel decodes anchored deltas in place (escaped streams fall back
    to a decoded dense view inside the kernel wrapper)."""
    sizes = sizes.astype(jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(sizes, dtype=jnp.int32)])
    src, dst, eid, in_pos, rank, valid, total = advance_fused_kernel(
        offsets, base.astype(jnp.int32), row_offsets, col_indices, cap_out,
        interpret=_interpret())
    return src, dst, eid, in_pos, rank, valid > 0, total


@B.register("advance_batch", B.PALLAS, encodings=("dense", "delta"))
def advance_fused_batch(row_offsets: jax.Array, col_indices,
                        base: jax.Array, sizes: jax.Array, cap_out: int):
    """Multi-source fused LB advance: base/sizes carry a leading batch
    axis; one pallas_call with an explicit (B, tiles) grid expands all
    lanes against the shared CSR. Contract mirrors "advance" with every
    output batched and totals (B,)."""
    sizes = sizes.astype(jnp.int32)
    offsets = jnp.concatenate(
        [jnp.zeros((sizes.shape[0], 1), jnp.int32),
         jnp.cumsum(sizes, axis=1, dtype=jnp.int32)], axis=1)
    src, dst, eid, in_pos, rank, valid, totals = advance_fused_batch_kernel(
        offsets, base.astype(jnp.int32), row_offsets, col_indices, cap_out,
        interpret=_interpret())
    return src, dst, eid, in_pos, rank, valid > 0, totals


@B.register("advance_filter", B.PALLAS, encodings=("dense", "delta"))
def advance_filter_fused(row_offsets: jax.Array, col_indices,
                         base: jax.Array, sizes: jax.Array,
                         visited: jax.Array, cap_out: int, cap_front: int):
    """Fused advance+filter megakernel: LB sorted search, CSR gathers,
    visited-bitmap predicate, exact first-occurrence culling and
    compacted emission in ONE pallas_call — the intermediate edge tuple
    never reaches HBM. Registry contract shared with the XLA
    composition in ``core.operators``: returns (ids, srcs, length,
    total) with ids/srcs (cap_front,) compacted survivors."""
    sizes = sizes.astype(jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(sizes, dtype=jnp.int32)])
    return advance_filter_fused_kernel(
        offsets, base.astype(jnp.int32), row_offsets, col_indices,
        visited, cap_out, cap_front, interpret=_interpret())


@B.register("advance_filter_batch", B.PALLAS, encodings=("dense", "delta"))
def advance_filter_fused_batch(row_offsets: jax.Array,
                               col_indices, base: jax.Array,
                               sizes: jax.Array, visited: jax.Array,
                               cap_out: int, cap_front: int):
    """Multi-source fused advance+filter on the (B, tiles) grid; per-lane
    bitmaps/outputs, shared CSR. Returns (ids, srcs, lengths, totals)
    with a leading batch axis."""
    sizes = sizes.astype(jnp.int32)
    offsets = jnp.concatenate(
        [jnp.zeros((sizes.shape[0], 1), jnp.int32),
         jnp.cumsum(sizes, axis=1, dtype=jnp.int32)], axis=1)
    return advance_filter_fused_batch_kernel(
        offsets, base.astype(jnp.int32), row_offsets, col_indices,
        visited, cap_out, cap_front, interpret=_interpret())


@B.register("segment_search", B.PALLAS)
def segment_search(haystack: jax.Array, lo: jax.Array, hi: jax.Array,
                   needles: jax.Array) -> jax.Array:
    """found[i] = needles[i] in sorted haystack[lo[i]:hi[i])."""
    return segment_search_kernel(haystack, lo, hi, needles,
                                 interpret=_interpret()) > 0


@B.register("spmm", B.PALLAS, encodings=("dense", "delta"))
def semiring_spmm(offsets: jax.Array, indices, values, x,
                  sr, ell_width, mask, row_seg=None) -> jax.Array:
    """Hybrid ELL+COO masked-semiring SpMM over a CSR structure —
    ``Y⟨mask⟩ = A ⊗ X`` with X (nx, k) dense. Registry contract shared
    with ``linalg.ops._spmm_xla``.

    Rows are packed to ELL width and swept by the fused masked-semiring
    row kernel ((k, tiles) grid); overflow edges of ultra-high-degree
    rows fall back to a semiring segment-reduce (the COO part).
    ``ell_width`` is static graph metadata chosen at build time
    (``Graph.ell_width`` / ``Graph.csc_ell_width`` via ``Graph.from_csr``)
    so this path performs no host synchronization and is jit-clean.

    ``indices`` may be a ``storage.EncodedCols`` delta stream: the ELL
    pack gathers through ``storage.gather_cols`` (decode per packed
    slot, escapes included), so the dense (m,) column array never
    materializes — the pack IS the decode. The semiring's ``precision``
    (``SR.with_precision(sr, "bf16")``) controls the ⊗ rounding inside
    the row kernel and on both fallback paths.
    """
    if ell_width is None:
        raise ValueError(
            "the pallas spmm/spmv needs a static ell_width; use "
            "Graph.ell_width / Graph.csc_ell_width (computed at build "
            "time by Graph.from_csr / from_edge_list) or pass one "
            "explicitly")
    n = offsets.shape[0] - 1
    m = St.store_num_edges(indices)
    deg = offsets[1:] - offsets[:-1]
    w = int(ell_width)
    lanes = jnp.arange(w, dtype=jnp.int32)[None, :]
    starts = offsets[:-1, None]
    idx = jnp.minimum(starts + lanes, m - 1)
    lane_ok = lanes < deg[:, None]
    nbrs = jnp.where(lane_ok, St.gather_cols(indices, idx), -1)
    vals = (jnp.where(lane_ok, jnp.float32(sr.one), 0.0)
            if values is None else values[idx].astype(jnp.float32))
    rowm = (jnp.ones((n,), jnp.int32) if mask is None
            else mask.astype(jnp.int32))
    y = semiring_ell_kernel(nbrs, vals, x, rowm, sr,
                            interpret=_interpret())
    # COO overflow: edges beyond the ELL width, ⊕-merged into the kernel
    # output (sound because masked-out rows are forced to the ⊕-identity
    # on both parts before the merge). ``row_seg`` is the loop-invariant
    # edge→row map (Graph build-time metadata); absent, derive it here.
    slot = jnp.arange(m, dtype=jnp.int32)
    if row_seg is None:
        row = jnp.searchsorted(offsets, slot, side="right") - 1
    else:
        row = row_seg
    row = jnp.clip(row, 0, n - 1)
    rank = slot - offsets[row]
    over = rank >= w
    xv = x[St.decode_cols(indices)]                       # (m, k)
    prod = (sr.round_prod(xv) if values is None
            else sr.mul_op(values[:, None], xv))
    prod = jnp.where(over[:, None], prod, sr.zero)
    y_over = sr.segment_reduce(prod.astype(jnp.float32), row, n,
                               indices_are_sorted=True)
    if mask is not None:
        y_over = jnp.where(mask[:, None], y_over, sr.zero)
    return sr.add_op(y, y_over).astype(jnp.float32)


@B.register("spmv", B.PALLAS, encodings=("dense", "delta"))
def semiring_spmv(offsets: jax.Array, indices, values, x,
                  sr, ell_width, mask, row_seg=None, over_pos=None,
                  over_row=None) -> jax.Array:
    """Masked-semiring SpMV — the k=1 column of the SpMM kernel. The
    compacted overflow metadata is the XLA hybrid's concern; the ELL
    kernel's COO remainder uses ``row_seg`` only."""
    del over_pos, over_row
    return semiring_spmm(offsets, indices, values, x[:, None], sr,
                         ell_width, mask, row_seg)[:, 0]


def _locate_pallas(haystack, lo, hi, needles):
    return segment_search_kernel(haystack, lo, hi, needles,
                                 interpret=_interpret(), locate=True)


def _register_mxm():
    # the shared dot-formulation machinery lives in linalg.ops; the
    # pallas flavour plugs in the fused LB expansion and the
    # position-returning probe kernel
    from repro.linalg.ops import make_mxm_impl
    B.register("mxm", B.PALLAS)(
        make_mxm_impl(advance_fused, _locate_pallas))


_register_mxm()


@B.register("compact", B.PALLAS)
def filter_compact(ids: jax.Array, keep: jax.Array):
    """Stable compaction of ids[keep] → (packed, count)."""
    return filter_compact_kernel(ids, keep, interpret=_interpret())


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, bq: int = 128,
                    bk: int = 128) -> jax.Array:
    """Fused single-head attention; vmap for (batch, heads)."""
    return flash_attention_kernel(q, k, v, causal=causal, bq=bq, bk=bk,
                                  interpret=_interpret())


def moe_gather(x: jax.Array, slot_token: jax.Array) -> jax.Array:
    """Gather token rows into expert-buffer slots (-1 ⇒ zero row)."""
    return moe_gather_kernel(x, slot_token, interpret=_interpret())


# re-export oracles for tests/benchmarks
oracle = ref


# ---------------------------------------------------------------------------
# Autotuner probes: representative kernel launches with a FORCED tile
# (the ``tile=`` static argument defeats the jit cache between candidate
# tiles). Registered here so ``tuner.autotune`` / the tuner CLI can
# measure without knowing kernel signatures. Synthetic inputs model the
# traversal hot path: a uniform-degree CSR sized to the capacity.
# ---------------------------------------------------------------------------


def _probe_graph(cap: int, encoding: str = "dense"):
    import numpy as np
    n = max(cap // 8, 16)
    deg = 8
    ro = np.arange(n + 1, dtype=np.int32) * deg
    ci = np.sort(np.random.default_rng(0).integers(
        0, n, size=(n, deg)).astype(np.int32), axis=1).ravel()
    if encoding == "delta":
        # measure the real in-kernel decode path: anchored uint16 stream
        seg = np.repeat(np.arange(n, dtype=np.int32), deg)
        return n, jnp.asarray(ro), St.encode_delta(ro, ci, seg)
    return n, jnp.asarray(ro), jnp.asarray(ci)


def _time(fn) -> float:
    import time
    out = fn()
    jax.block_until_ready(jax.tree_util.tree_leaves(out))
    t0 = time.monotonic()
    out = fn()
    jax.block_until_ready(jax.tree_util.tree_leaves(out))
    return time.monotonic() - t0


def _probe_advance(cap: int, tile: int, encoding: str = "dense") -> float:
    n, ro, ci = _probe_graph(cap, encoding)
    k = min(n, max(cap // 8, 1))
    base = jnp.arange(k, dtype=jnp.int32) % n
    sizes = jnp.full((k,), 8, jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(sizes, dtype=jnp.int32)])
    return _time(lambda: advance_fused_kernel(
        offsets, base, ro, ci, cap, interpret=_interpret(), tile=tile))


def _probe_advance_filter(cap: int, tile: int,
                          encoding: str = "dense") -> float:
    if tile > 4096:
        # in-tile culling is O(tile²) (the lane comparison matrix);
        # tiles past 4k are never competitive and the probe's matrix
        # alone would be gigabytes — skip the candidate
        raise ValueError("advance_filter tile too large to probe")
    n, ro, ci = _probe_graph(cap, encoding)
    k = min(n, max(cap // 8, 1))
    base = jnp.arange(k, dtype=jnp.int32) % n
    sizes = jnp.full((k,), 8, jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(sizes, dtype=jnp.int32)])
    visited = jnp.zeros((n,), jnp.int32)
    return _time(lambda: advance_filter_fused_kernel(
        offsets, base, ro, ci, visited, cap, min(cap, n),
        interpret=_interpret(), tile=tile))


def _probe_compact(cap: int, tile: int) -> float:
    ids = jnp.arange(cap, dtype=jnp.int32)
    keep = (ids % 3) == 0
    return _time(lambda: filter_compact_kernel(
        ids, keep, interpret=_interpret(), tile=tile))


def _probe_lb_expand(cap: int, tile: int) -> float:
    k = max(cap // 8, 1)
    sizes = jnp.full((k,), 8, jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(sizes, dtype=jnp.int32)])
    return _time(lambda: lb_expand_kernel(
        offsets, cap, interpret=_interpret(), tile=tile))


def _probe_spmv(cap: int, tile: int) -> float:
    import numpy as np
    n = max(cap, 16)
    w = 8
    rng = np.random.default_rng(0)
    nbrs = jnp.asarray(rng.integers(0, n, size=(n, w)).astype(np.int32))
    vals = jnp.ones((n, w), jnp.float32)
    x = jnp.ones((n, 1), jnp.float32)
    mask = jnp.ones((n,), jnp.int32)
    from repro.linalg import semiring as SR
    return _time(lambda: semiring_ell_kernel(
        nbrs, vals, x, mask, SR.plus_times, interpret=_interpret(),
        tile=tile))


tuner.register_probe("advance", _probe_advance)
tuner.register_probe("advance_filter", _probe_advance_filter)
tuner.register_probe("compact", _probe_compact)
tuner.register_probe("lb_expand", _probe_lb_expand)
tuner.register_probe("spmv", _probe_spmv)
