"""Kernel autotuner: measured tile/grid selection per (op, tier, platform).

The Pallas kernels used to pick tiles with hardcoded heuristics
(``MIN_TILE = 512`` doubled until the grid fit under ``MAX_GRID``),
which bakes one platform's tradeoff into every kernel: interpret mode
wants few grid steps (each costs a host round trip), compiled TPU wants
tiles sized to VMEM residency and pipeline depth. This module owns the
choice:

  * ``tile_for(op, cap)`` — the one lookup every kernel wrapper calls at
    trace time (caps are static, so this is plain Python). Measured
    entries from the JSON cache win; otherwise the clamped default
    heuristic below.
  * ``autotune(op, cap, probe)`` — measure candidate tiles with the
    op's registered probe and persist the winner. Never triggered
    implicitly from inside a trace: the benchmark harness
    (``benchmarks/frontier_scaling.py --tune``) and the CLI
    (``python -m repro.kernels.tuner``) drive it at top level.

Cache format (JSON, committed or pointed at via ``REPRO_TUNE_CACHE``):

    {"version": 2,
     "entries": {"<op>|<tier>|<platform>|<encoding>": {"tile": 1024,
                                                       "ms": 0.41, ...}}}

``tier`` is the power-of-two bucket of the capacity (the same ladder the
tiered dispatch in ``core.backend`` switches over), ``platform`` comes
from ``runtime.platform()`` — interpret-mode measurements never leak
onto compiled TPU runs — and ``encoding`` is the column storage format
("dense" | "delta", PR 6): a delta-decoding kernel does strictly more
VPU work per lane than a dense gather, so its best tile is measured
separately. Bumping ``_VERSION`` invalidates every entry (schema or
cost-model changes — version 1 entries lacked the encoding axis and are
dropped on load); unknown versions are ignored, never deleted.

Env switches:
  REPRO_TUNE=0       ignore the cache entirely (pure heuristic defaults)
  REPRO_TUNE=1       allow ``autotune`` to (re)measure and persist
  REPRO_TUNE_CACHE   cache path (default: tuner_cache.json next to this
                     module — the committed cache)

Tile choice never affects results — kernels pad to the tile and slice
back — so a stale or missing cache is a performance bug, never a
correctness one (the parity suite runs identically under any cache).
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Optional

# v2: cache keys gained the storage-encoding axis (PR 6); v1 entries
# (no encoding suffix) are invalidated wholesale on load.
_VERSION = 2

DEFAULT_MIN_TILE = 512
DEFAULT_MAX_GRID = 128

# op -> probe(cap, tile) -> seconds; registered by kernel modules so the
# CLI / bench can measure without knowing kernel call signatures.
PROBES: Dict[str, Callable[[int, int], float]] = {}

_cache: Optional[dict] = None
# in-memory cache validity key: (path, mtime, size) — path so a
# REPRO_TUNE_CACHE switch reloads, size so same-mtime rewrites (coarse
# filesystem clocks) cannot serve stale entries
_cache_key: Optional[tuple] = None


def cache_path() -> str:
    return os.environ.get(
        "REPRO_TUNE_CACHE",
        os.path.join(os.path.dirname(__file__), "tuner_cache.json"))


def _enabled() -> bool:
    return os.environ.get("REPRO_TUNE", "") != "0"


def _load() -> dict:
    global _cache, _cache_key
    path = cache_path()
    try:
        st = os.stat(path)
        key = (path, st.st_mtime_ns, st.st_size)
    except OSError:
        _cache, _cache_key = {"version": _VERSION, "entries": {}}, None
        return _cache
    if _cache is None or key != _cache_key:
        try:
            with open(path) as f:
                raw = json.load(f)
        except (OSError, ValueError):
            raw = {}
        if raw.get("version") != _VERSION:
            raw = {"version": _VERSION, "entries": {}}
        raw.setdefault("entries", {})
        _cache, _cache_key = raw, key
    return _cache


def _persist(cache: dict) -> None:
    global _cache_key
    path = cache_path()
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(cache, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    st = os.stat(path)
    _cache_key = (path, st.st_mtime_ns, st.st_size)


def pow2_ceil(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def tier_of(cap: int, min_tile: int = DEFAULT_MIN_TILE) -> int:
    """Power-of-two bucket a capacity falls in — the cache key's tier
    axis and the capacity ladder's rung (core.backend.tier_plan)."""
    return max(min(pow2_ceil(max(cap, 1)), 1 << 30), min_tile)


def _key(op: str, cap: int, platform: str, min_tile: int,
         encoding: str = "dense") -> str:
    return f"{op}|{tier_of(cap, min_tile)}|{platform}|{encoding}"


def default_tile(cap: int, lanes: int = 1,
                 min_tile: int = DEFAULT_MIN_TILE,
                 max_grid: int = DEFAULT_MAX_GRID) -> int:
    """Untuned heuristic: smallest power-of-two tile ≥ ``min_tile``
    keeping the (lanes × tiles) grid ≤ ``max_grid``, clamped to the
    padded output size — a tile can never exceed pow2_ceil(cap), so a
    small capacity (a low tier) no longer inflates VMEM block sizes to
    ``min_tile`` × doublings it cannot use."""
    hi = pow2_ceil(max(cap, 1))
    tile = min(min_tile, hi)
    while lanes * (-(-cap // tile)) > max_grid and tile < hi:
        tile *= 2
    return tile


def tile_for(op: str, cap: int, *, lanes: int = 1,
             min_tile: int = DEFAULT_MIN_TILE,
             max_grid: int = DEFAULT_MAX_GRID,
             encoding: str = "dense") -> int:
    """Tile size for one kernel launch of ``op`` at capacity ``cap``
    under column storage ``encoding``.

    Called at trace time with static values. A measured cache entry for
    (op, tier(cap), platform, encoding) wins; a dense measurement at the
    same tier is the second choice for an unmeasured delta launch (same
    memory shape, slightly more per-lane work); the clamped heuristic is
    the fallback. The returned tile is always ≤ pow2_ceil(cap).
    """
    if _enabled():
        from . import runtime
        entries = _load()["entries"]
        entry = entries.get(_key(op, cap, runtime.platform(), min_tile,
                                 encoding))
        if entry is None and encoding != "dense":
            entry = entries.get(_key(op, cap, runtime.platform(), min_tile))
        if entry and "tile" in entry:
            return min(int(entry["tile"]), pow2_ceil(max(cap, 1)))
    return default_tile(cap, lanes=lanes, min_tile=min_tile,
                        max_grid=max_grid)


def tier_floor(op: str, default: int = DEFAULT_MIN_TILE) -> int:
    """Floor for ``op``'s capacity-tier ladder (core.backend.tier_plan):
    the RAW measured tile at the bottom tier bucket when one exists —
    deliberately unclamped, unlike ``tile_for`` — so a platform whose
    measurements want big tiles (compiled TPU pipelines) never gets
    capacity tiers smaller than one kernel tile (they would pad right
    back up, buying switch overhead for nothing)."""
    if _enabled():
        from . import runtime
        entry = _load()["entries"].get(
            _key(op, default, runtime.platform(), default))
        if entry and "tile" in entry:
            return max(int(entry["tile"]), default)
    return default


def register_probe(op: str, fn: Callable[[int, int], float]) -> None:
    """Register ``fn(cap, tile) -> seconds`` as the measurement probe
    for ``op`` (called by kernel modules at import)."""
    PROBES[op] = fn


def candidates(cap: int, min_tile: int = 128) -> list[int]:
    hi = pow2_ceil(max(cap, 1))
    out, t = [], min(min_tile, hi)
    while t <= hi:
        out.append(t)
        t *= 2
    return out


def autotune(op: str, cap: int, probe: Optional[Callable] = None, *,
             repeats: int = 3, force: bool = False,
             min_tile: int = DEFAULT_MIN_TILE,
             encoding: str = "dense") -> int:
    """Measure candidate tiles for ``op`` at ``cap`` and persist the
    winner under (op, tier, platform, encoding). Requires REPRO_TUNE=1
    (or ``force=True``); must run at top level, never inside a trace.
    Returns the selected tile."""
    from . import runtime
    probe = probe or PROBES.get(op)
    if probe is None:
        raise KeyError(f"no tuning probe registered for op {op!r}")
    if not force and os.environ.get("REPRO_TUNE") != "1":
        return tile_for(op, cap, min_tile=min_tile, encoding=encoding)
    cache = _load()
    key = _key(op, cap, runtime.platform(), min_tile, encoding)
    if not force and key in cache["entries"]:
        return int(cache["entries"][key]["tile"])
    # probes that model the storage encoding accept it as a kwarg; the
    # others measure their one (dense) workload under any key
    import inspect
    kw = ({"encoding": encoding}
          if "encoding" in inspect.signature(probe).parameters else {})
    best_tile, best_s = None, float("inf")
    for tile in candidates(cap):
        try:
            probe(cap, tile, **kw)                   # compile / warm
            s = min(probe(cap, tile, **kw) for _ in range(repeats))
        except Exception:  # reprolint: disable=RL006 -- probe boundary: an unsupported tile is a skip, not a failure
            continue
        if s < best_s:
            best_tile, best_s = tile, s
    from repro.obs.log import get_logger
    log = get_logger("tuner")
    if best_tile is None:
        log.debug(f"{op} cap={cap}: no candidate tile survived, "
                  f"using heuristic")
        return tile_for(op, cap, min_tile=min_tile, encoding=encoding)
    log.debug(f"{op} cap={cap} {encoding}: picked tile {best_tile} "
              f"({best_s * 1e3:.3f} ms)")
    cache["entries"][key] = {"tile": int(best_tile),
                             "ms": round(best_s * 1e3, 4),
                             "cap": int(cap),
                             "stamp": time.strftime("%Y-%m-%d")}
    _persist(cache)
    return best_tile


def autotune_all(caps: list[int], ops: Optional[list[str]] = None,
                 force: bool = True) -> dict:
    """Tune every registered probe over a capacity ladder (the CLI /
    bench entry point). Returns {(op, cap, encoding): tile}. Ops whose
    probe models the storage encoding are measured once per encoding;
    the rest get one dense measurement."""
    import inspect
    picked = {}
    for op in (ops or sorted(PROBES)):
        encodings = (("dense", "delta")
                     if "encoding" in inspect.signature(
                         PROBES[op]).parameters else ("dense",))
        for cap in caps:
            for enc in encodings:
                picked[(op, cap, enc)] = autotune(op, cap, force=force,
                                                  encoding=enc)
    return picked


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description="kernel autotuner")
    ap.add_argument("--ops", default=None,
                    help="comma-separated op subset (default: all probes)")
    ap.add_argument("--caps", default="512,2048,8192,32768,131072",
                    help="comma-separated capacities to tune at")
    args = ap.parse_args(argv)
    import repro.kernels.ops  # noqa: F401  (registers the probes)
    ops = args.ops.split(",") if args.ops else None
    caps = [int(c) for c in args.caps.split(",")]
    from repro.obs.log import get_logger
    log = get_logger("tuner")
    picked = autotune_all(caps, ops)
    for (op, cap, enc), tile in sorted(picked.items()):
        log.info(f"{op:16s} cap={cap:<8d} {enc:5s} -> tile {tile}")
    log.info(f"cache: {cache_path()}")


if __name__ == "__main__":
    main()
