"""Pure-jnp oracles for every Pallas kernel (the ref.py contract).

Each function is the semantic specification its kernel is tested against
(tests sweep shapes/dtypes and assert_allclose kernel vs. oracle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lb_expand_ref(offsets: jax.Array, cap_out: int):
    """Merge-based LB expansion geometry.

    offsets: (cap_in+1,) int32 exclusive prefix sum of segment sizes with
    the total in the last slot. Returns (in_pos, rank, valid) each
    (cap_out,) — which input segment each output slot belongs to.
    """
    cap_in = offsets.shape[0] - 1
    slots = jnp.arange(cap_out, dtype=jnp.int32)
    in_pos = jnp.searchsorted(offsets[:-1], slots,
                              side="right").astype(jnp.int32) - 1
    in_pos = jnp.clip(in_pos, 0, max(cap_in - 1, 0))
    rank = slots - offsets[in_pos]
    valid = slots < offsets[-1]
    return in_pos, rank, valid.astype(jnp.int32)


def spmv_ell_ref(nbrs: jax.Array, vals: jax.Array, x: jax.Array):
    """ELL-format SpMV: y[i] = Σ_w vals[i,w] · x[nbrs[i,w]] (nbrs −1 = pad)."""
    mask = nbrs >= 0
    safe = jnp.where(mask, nbrs, 0)
    return jnp.sum(jnp.where(mask, vals * x[safe], 0.0), axis=1)


def semiring_ell_ref(nbrs: jax.Array, vals: jax.Array, x: jax.Array,
                     mask: jax.Array, sr):
    """Masked-semiring ELL SpMM oracle: y[i,b] = ⊕_w vals[i,w] ⊗
    x[nbrs[i,w], b]; masked-out rows hold the ⊕-identity."""
    ok = nbrs >= 0
    safe = jnp.where(ok, nbrs, 0)
    g = x[safe]                                    # (n, W, k)
    prod = sr.mul_op(vals[..., None], g)
    prod = jnp.where(ok[..., None], prod, sr.zero)
    red = sr.add_reduce(prod, axis=1)              # (n, k)
    return jnp.where((mask > 0)[:, None], red, sr.zero)


def segment_search_ref(haystack: jax.Array, lo: jax.Array, hi: jax.Array,
                       needles: jax.Array):
    """found[i] = needles[i] ∈ haystack[lo[i]:hi[i]) (segments sorted)."""
    def one(l, h, v):
        idx = jnp.searchsorted(haystack, v)
        # walk: first position >= v within [l, h)
        pos = jnp.clip(idx, l, haystack.shape[0] - 1)
        # searchsorted is global; redo bounded search via where-scan
        inside = (jnp.arange(haystack.shape[0]) >= l) & \
                 (jnp.arange(haystack.shape[0]) < h)
        return jnp.any(inside & (haystack == v))
    return jax.vmap(one)(lo, hi, needles).astype(jnp.int32)


def filter_compact_ref(ids: jax.Array, keep: jax.Array):
    """Stable compaction: kept ids packed to the front, -1 padding.
    Returns (packed, count)."""
    cap = ids.shape[0]
    keep = keep.astype(bool)
    keep_i = keep.astype(jnp.int32)
    pos = jnp.cumsum(keep_i, dtype=jnp.int32) - keep_i
    out = jnp.full((cap,), -1, ids.dtype)
    tgt = jnp.where(keep, pos, cap)
    out = out.at[tgt].set(ids, mode="drop")
    # dtype= keeps the count int32 under jax_enable_x64
    return out, jnp.sum(keep, dtype=jnp.int32)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, scale: float | None = None):
    """Single-head attention oracle. q:(Sq,D) k,v:(Sk,D)."""
    d = q.shape[-1]
    scale = (1.0 / jnp.sqrt(d)) if scale is None else scale
    logits = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale
    if causal:
        sq, sk = q.shape[0], k.shape[0]
        # align the ends: query i attends keys j <= i + (sk - sq)
        qpos = jnp.arange(sq)[:, None] + (sk - sq)
        kpos = jnp.arange(sk)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (sq > sk under causal alignment): define as 0,
    # matching the kernel's zero-normalizer convention
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return (p @ v.astype(jnp.float32)).astype(q.dtype)


def moe_gather_ref(x: jax.Array, slot_token: jax.Array):
    """Gather token rows into expert slots. slot_token: (S,) int32 token id
    per expert-buffer slot, -1 = empty. Returns (S, D)."""
    mask = slot_token >= 0
    safe = jnp.where(mask, slot_token, 0)
    return jnp.where(mask[:, None], x[safe], 0.0).astype(x.dtype)
