"""Pallas kernel: FlashAttention-style fused attention (online softmax).

The LM substrate's kernel-fusion showcase (the paper's fusion philosophy
applied at the model layer): one kernel streams KV tiles through VMEM,
keeping running max / normalizer / accumulator in scratch, so the (Sq, Sk)
score matrix never exists in HBM — turning the memory-roofline term of
attention from O(Sq·Sk) to O(Sq·D + Sk·D).

Grid: (q_tiles, kv_tiles), kv innermost; scratch persists across the kv
sweep of each q tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import runtime

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, sq: int, sk: int, bq: int, bk: int,
            nk: int):
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = ki * bk + jax.lax.iota(jnp.int32, bk)
    mask = (kpos < sk)[None, :]
    if causal:
        qpos = qi * bq + jax.lax.iota(jnp.int32, bq) + (sk - sq)
        mask = mask & (kpos[None, :] <= qpos[:, None])
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    l_cur = alpha * l_prev + jnp.sum(p, axis=-1)
    acc = acc_scr[...] * alpha[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_cur
    l_scr[...] = l_cur
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _fin():
        o_ref[...] = (acc_scr[...]
                      / jnp.maximum(l_scr[...], 1e-30)[:, None]
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, bq: int = DEFAULT_BQ,
                           bk: int = DEFAULT_BK,
                           interpret: bool | None = None) -> jax.Array:
    """Single-head fused attention. q: (Sq, D); k, v: (Sk, D)."""
    interpret = runtime.interpret_mode(interpret)
    sq, d = q.shape
    sk = k.shape[0]
    scale = float(1.0 / (d ** 0.5))
    bq = min(bq, max(8, sq))
    bk = min(bk, max(8, sk))
    psq = -(-sq // bq) * bq
    psk = -(-sk // bk) * bk
    qp = jnp.pad(q, ((0, psq - sq), (0, 0)))
    kp = jnp.pad(k, ((0, psk - sk), (0, 0)))
    vp = jnp.pad(v, ((0, psk - sk), (0, 0)))
    nq, nk = psq // bq, psk // bk
    # the (i, 0) output map revisits each q block across the k axis —
    # the online-softmax accumulation; declared for the memory sanitizer
    out = runtime.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, sq=sq,
                          sk=sk, bq=bq, bk=bk, nk=nk),
        name="flash_attention",
        accumulate=(0,),
        grid=(nq, nk),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((psq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:sq]
