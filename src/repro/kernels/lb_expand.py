"""Pallas kernel: merge-based load-balanced expansion (LB, paper §5.1.3).

The advance operator's heart: map each output slot to its (input segment,
rank) pair by binary-searching the degree prefix-sum. On the GPU this is
Davidson et al.'s load-balanced search; on TPU it becomes a dense,
perfectly regular VPU loop — every lane does ceil(log2(cap_in)) compares.

Grid: one program per output tile. The offsets array stays resident in
VMEM across the whole grid (BlockSpec maps every program to block 0);
output tiles stream.
"""
from __future__ import annotations

import functools

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import runtime, tuner


def _kernel(offsets_ref, in_pos_ref, rank_ref, valid_ref, *, cap_in: int,
            iters: int, tile: int):
    t = pl.program_id(0)
    offsets = offsets_ref[...]          # (cap_in + 1,)
    slots = t * tile + jax.lax.iota(jnp.int32, tile)
    total = offsets[cap_in]

    # upper-bound binary search over offsets[0:cap_in] (exclusive scan)
    lo = jnp.zeros((tile,), jnp.int32)
    hi = jnp.full((tile,), cap_in, jnp.int32)

    def body(_, carry):
        lo_, hi_ = carry
        mid = (lo_ + hi_) // 2
        go_right = offsets[jnp.clip(mid, 0, cap_in)] <= slots
        lo_ = jnp.where(go_right & (lo_ < hi_), mid + 1, lo_)
        hi_ = jnp.where(~go_right & (lo_ < hi_), mid, hi_)
        return lo_, hi_

    lo, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    pos = jnp.clip(lo - 1, 0, max(cap_in - 1, 0))
    in_pos_ref[...] = pos
    rank_ref[...] = slots - offsets[pos]
    valid_ref[...] = (slots < total).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("cap_out", "interpret", "tile"))
def lb_expand_kernel(offsets: jax.Array, cap_out: int,
                     interpret: bool | None = None,
                     tile: int | None = None):
    """offsets: (cap_in+1,) int32 exclusive prefix sum (total in last slot).
    Returns (in_pos, rank, valid) each (cap_out,) int32."""
    interpret = runtime.interpret_mode(interpret)
    cap_in = offsets.shape[0] - 1
    if tile is None:
        tile = tuner.tile_for("lb_expand", cap_out)
    padded = -(-cap_out // tile) * tile
    iters = max(math.ceil(math.log2(max(cap_in, 2))) + 1, 1)
    grid = (padded // tile,)
    out_shape = [jax.ShapeDtypeStruct((padded,), jnp.int32)] * 3
    in_pos, rank, valid = runtime.pallas_call(
        functools.partial(_kernel, cap_in=cap_in, iters=iters, tile=tile),
        name="lb_expand",
        grid=grid,
        in_specs=[pl.BlockSpec((cap_in + 1,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((tile,), lambda i: (i,))] * 3,
        out_shape=out_shape,
        interpret=interpret,
    )(offsets)
    return in_pos[:cap_out], rank[:cap_out], valid[:cap_out]
