"""Pallas kernel: tile-local stream compaction — the filter operator's core
(paper §4.2, Merrill's local-scan filtering strategy §5.2.1).

Phase 1 (this kernel): each tile compacts its kept items to the front of
its own output tile (tile-local scan + one-hot gather — the TPU-native
scatter: a comparison matrix instead of per-thread scattered writes) and
emits its count.
Phase 2 (ops.py, jnp): exclusive-scan the tile counts and gather tiles to
their global offsets — Merrill's 'coarse-grained global offsets' pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import runtime, tuner

TILE = 256          # heuristic floor; the tuner may pick larger tiles


def _kernel(ids_ref, keep_ref, packed_ref, count_ref, *, tile: int):
    ids = ids_ref[...]                       # (tile,)
    keep = keep_ref[...] > 0                 # (tile,)
    keep_i = keep.astype(jnp.int32)
    pos = jnp.cumsum(keep_i, dtype=jnp.int32) - keep_i
    lane = jax.lax.iota(jnp.int32, tile)
    # one-hot "scatter": packed[j] = ids[i] where pos[i]==j and keep[i]
    onehot = (pos[:, None] == lane[None, :]) & keep[:, None]
    # dtype= pins the accumulator: under jax_enable_x64 an int32 sum
    # would promote to int64 and fail the int32 output-ref swap
    packed = jnp.sum(jnp.where(onehot, ids[:, None], 0), axis=0,
                     dtype=ids.dtype)
    cnt = jnp.sum(keep, dtype=jnp.int32)
    packed_ref[...] = jnp.where(lane < cnt, packed, -1)
    count_ref[...] = jnp.full((1,), cnt, jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "tile"))
def filter_compact_kernel(ids: jax.Array, keep: jax.Array,
                          interpret: bool | None = None,
                          tile: int | None = None):
    """Compact ids[keep] (stable). Returns (packed (cap,), count ()).

    cap = len(ids); tail is -1 padding.
    """
    interpret = runtime.interpret_mode(interpret)
    cap = ids.shape[0]
    if tile is None:
        tile = tuner.tile_for("compact", cap, min_tile=TILE)
    padded = -(-cap // tile) * tile
    if padded != cap:
        pad = padded - cap
        ids = jnp.concatenate([ids, jnp.full((pad,), -1, ids.dtype)])
        keep = jnp.concatenate([keep.astype(jnp.int32),
                                jnp.zeros((pad,), jnp.int32)])
    else:
        keep = keep.astype(jnp.int32)
    ntile = padded // tile
    packed, counts = runtime.pallas_call(
        functools.partial(_kernel, tile=tile),
        name="filter_compact",
        grid=(ntile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((padded,), ids.dtype),
                   jax.ShapeDtypeStruct((ntile,), jnp.int32)],
        interpret=interpret,
    )(ids, keep)
    # phase 2: global reassembly (coarse offsets + gather)
    offsets = jnp.cumsum(counts) - counts
    lane = jnp.arange(padded, dtype=jnp.int32)
    tile_of = lane // tile
    local = lane % tile
    src = tile_of * tile + local
    gpos = offsets[tile_of] + local
    out = jnp.full((padded,), -1, ids.dtype)
    valid = local < counts[tile_of]
    out = out.at[jnp.where(valid, gpos, padded)].set(packed[src],
                                                     mode="drop")
    total = jnp.sum(counts, dtype=jnp.int32)
    return out[:cap], total
