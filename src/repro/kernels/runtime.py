"""Shared Pallas runtime probes: interpret-mode selection and platform id.

Every kernel module used to hardcode ``interpret: bool = True`` defaults
while ``kernels/ops.py`` carried its own platform probe — two sources of
truth that could drift per callsite (a TPU build would silently run some
kernels interpreted). This module is now the single probe:

  * ``interpret_mode(explicit)`` — the one interpret decision. Explicit
    ``True``/``False`` wins; otherwise ``REPRO_FORCE_INTERPRET`` (any
    value but "0"/"false"); otherwise interpret everywhere except a real
    TPU backend. Every kernel wrapper defaults ``interpret=None`` and
    resolves through here at trace time, so TPU compiles natively
    everywhere with zero per-module opt-in.
  * ``platform()`` — the string the autotuner keys its cache on
    ("tpu" | "cpu+interpret" | …): tile choices measured in interpret
    mode must never be replayed on compiled TPU kernels and vice versa.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

ENV_VAR = "REPRO_FORCE_INTERPRET"


def interpret_mode(explicit: Optional[bool] = None) -> bool:
    """Resolve the interpret flag for a pallas_call.

    Precedence: explicit bool > REPRO_FORCE_INTERPRET env > platform
    probe (native only on TPU). Resolution happens when the kernel
    TRACES: with ``interpret=None`` the jit cache key is ``None``, so a
    mid-process env flip does NOT retrace already-compiled shapes.
    Callers that must honor env flips per call resolve eagerly and pass
    the concrete bool (``kernels/ops.py`` does exactly this for every
    registry path); the env var is primarily a process-level debug
    switch set before the first call.
    """
    if explicit is not None:
        return bool(explicit)
    env = os.environ.get(ENV_VAR)
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def platform() -> str:
    """Tuner cache key: the execution platform a measurement is valid
    for. Interpret mode is its own platform — its cost model (one host
    round trip per grid step) is unrelated to compiled-kernel cost."""
    base = jax.default_backend()
    return base if not interpret_mode() else f"{base}+interpret"
