"""Shared Pallas runtime probes: interpret mode, platform id, call gate.

Every kernel module used to hardcode ``interpret: bool = True`` defaults
while ``kernels/ops.py`` carried its own platform probe — two sources of
truth that could drift per callsite (a TPU build would silently run some
kernels interpreted). This module is now the single probe:

  * ``interpret_mode(explicit)`` — the one interpret decision. Explicit
    ``True``/``False`` wins; otherwise ``REPRO_FORCE_INTERPRET`` (any
    value but "0"/"false"); otherwise interpret everywhere except a real
    TPU backend. Every kernel wrapper defaults ``interpret=None`` and
    resolves through here at trace time, so TPU compiles natively
    everywhere with zero per-module opt-in.
  * ``platform()`` — the string the autotuner keys its cache on
    ("tpu" | "cpu+interpret" | …): tile choices measured in interpret
    mode must never be replayed on compiled TPU kernels and vice versa.
  * ``pallas_call(...)`` — the one gate every kernel wrapper launches
    through. Identical to ``pl.pallas_call`` when sanitizing is off;
    under ``REPRO_SANITIZE=1`` (or ``analysis.sanitize.sanitizing()``)
    it audits the grid/BlockSpec addressing against the actual operand
    shapes at trace time (out-of-bounds tile maps, undeclared
    write-write races between grid cells) before launching. Wrappers
    whose outputs are legitimately revisited across sequential grid
    steps declare them with ``accumulate=``.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence

import jax
from jax.experimental import pallas as pl

from repro.analysis import sanitize

ENV_VAR = "REPRO_FORCE_INTERPRET"


def interpret_mode(explicit: Optional[bool] = None) -> bool:
    """Resolve the interpret flag for a pallas_call.

    Precedence: explicit bool > REPRO_FORCE_INTERPRET env > platform
    probe (native only on TPU). Resolution happens when the kernel
    TRACES: with ``interpret=None`` the jit cache key is ``None``, so a
    mid-process env flip does NOT retrace already-compiled shapes.
    Callers that must honor env flips per call resolve eagerly and pass
    the concrete bool (``kernels/ops.py`` does exactly this for every
    registry path); the env var is primarily a process-level debug
    switch set before the first call.
    """
    if explicit is not None:
        return bool(explicit)
    env = os.environ.get(ENV_VAR)
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def platform() -> str:
    """Tuner cache key: the execution platform a measurement is valid
    for. Interpret mode is its own platform — its cost model (one host
    round trip per grid step) is unrelated to compiled-kernel cost."""
    base = jax.default_backend()
    return base if not interpret_mode() else f"{base}+interpret"


def pallas_call(kernel, *, grid, in_specs, out_specs, out_shape,
                interpret: bool = False, name: Optional[str] = None,
                accumulate: Sequence[int] = (), scratch_shapes=None):
    """``pl.pallas_call`` with the memory sanitizer attached.

    Returns the launch callable. With sanitizing off this is exactly the
    ``pl.pallas_call`` result; with it on, the returned callable first
    audits every operand's BlockSpec against its *actual* shape
    (``sanitize.check_pallas_spec``), then launches. The audit runs at
    trace time — it sees concrete shapes/grids even inside jit and costs
    nothing in the compiled program.

    ``accumulate`` lists output positions whose blocks are revisited by
    design across (sequential) grid steps; any other revisit faults as a
    write-write race. ``name`` labels faults (defaults to the kernel
    function's name).
    """
    extra = {} if scratch_shapes is None else {
        "scratch_shapes": scratch_shapes}
    call = pl.pallas_call(kernel, grid=grid, in_specs=list(in_specs),
                          out_specs=out_specs, out_shape=out_shape,
                          interpret=interpret, **extra)
    if not sanitize.enabled():
        return call
    label = name or getattr(kernel, "__name__", None) or "pallas_call"
    multi_out = isinstance(out_shape, (list, tuple))
    out_specs_l = list(out_specs) if multi_out else [out_specs]
    out_shapes = [tuple(s.shape) for s in
                  (out_shape if multi_out else [out_shape])]

    def checked(*operands):
        sanitize.check_pallas_spec(
            name=label, grid=grid, in_specs=list(in_specs),
            out_specs=out_specs_l,
            in_shapes=[tuple(o.shape) for o in operands],
            out_shapes=out_shapes, accumulate=accumulate)
        return call(*operands)

    return checked
