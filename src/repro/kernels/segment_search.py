"""Pallas kernel: batched bounded binary search — the SmallLarge probe of
segmented intersection (paper §4.3).

Each lane searches needles[i] within haystack[lo[i]:hi[i]). The haystack
(the graph's column-indices array) stays VMEM-resident across the grid;
needle/bound tiles stream. All lanes run the same ceil(log2(max_deg))
compare steps — fully regular VPU work, replacing the GPU's per-thread
merge-path partitioning.
"""
from __future__ import annotations

import functools

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import runtime, tuner

TILE = 512


def _kernel(hay_ref, lo_ref, hi_ref, needle_ref, found_ref, *, iters: int,
            locate: bool = False):
    hay = hay_ref[...]
    lo = lo_ref[...]
    hi = hi_ref[...]
    needles = needle_ref[...]
    hmax = hay.shape[0] - 1

    def body(_, carry):
        lo_, hi_ = carry
        mid = (lo_ + hi_) // 2
        mv = hay[jnp.clip(mid, 0, hmax)]
        go_right = mv < needles
        lo_ = jnp.where(go_right & (lo_ < hi_), mid + 1, lo_)
        hi_ = jnp.where(~go_right & (lo_ < hi_), mid, hi_)
        return lo_, hi_

    lo_f, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    in_range = lo_f < hi
    found = in_range & (hay[jnp.clip(lo_f, 0, hmax)] == needles)
    if locate:
        found_ref[...] = jnp.where(found, lo_f, -1).astype(jnp.int32)
    else:
        found_ref[...] = found.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "locate"))
def segment_search_kernel(haystack: jax.Array, lo: jax.Array, hi: jax.Array,
                          needles: jax.Array,
                          interpret: bool | None = None,
                          locate: bool = False) -> jax.Array:
    """found[i] ∈ {0,1} for needles[i] in haystack[lo[i]:hi[i]).

    With ``locate=True`` returns the matched *position* instead (int32
    index into ``haystack``, −1 when absent) — the value-gathering probe
    the semiring SpGEMM needs (B's stored value at the match feeds the
    ⊗ combine).
    """
    interpret = runtime.interpret_mode(interpret)
    cap = needles.shape[0]
    tile = tuner.tile_for("segment_search", cap, min_tile=TILE)
    padded = -(-cap // tile) * tile
    if padded != cap:
        pad = padded - cap
        z = jnp.zeros((pad,), jnp.int32)
        lo = jnp.concatenate([lo.astype(jnp.int32), z])
        hi = jnp.concatenate([hi.astype(jnp.int32), z])
        needles = jnp.concatenate([needles, z - 1])
    else:
        lo = lo.astype(jnp.int32)
        hi = hi.astype(jnp.int32)
    iters = max(math.ceil(math.log2(max(haystack.shape[0], 2))) + 1, 1)
    found = runtime.pallas_call(
        functools.partial(_kernel, iters=iters, locate=locate),
        name="segment_search",
        grid=(padded // tile,),
        in_specs=[
            pl.BlockSpec(haystack.shape, lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.int32),
        interpret=interpret,
    )(haystack, lo, hi, needles)
    return found[:cap]
