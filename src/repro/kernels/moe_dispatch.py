"""Pallas kernel: MoE dispatch gather — the paper's technique beyond the
paper (DESIGN.md §4).

Token→expert routing is a bipartite V→E advance: the LB machinery
(lb_expand / sort by expert) decides which token lands in which expert
buffer slot; this kernel performs the actual data movement — gathering
token embedding rows into contiguous per-expert buffers so the expert
matmuls run dense. Slot = -1 ⇒ capacity-dropped (Gunrock's inexact
filter), producing a zero row.

Grid: one program per slot tile; the token matrix stays VMEM-resident
(fits for the per-device token counts the framework produces after
sequence/data sharding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import runtime

TILE_S = 128


def _kernel(slot_ref, x_ref, out_ref):
    slots = slot_ref[...]                   # (TILE_S,)
    x = x_ref[...]                          # (T, D) resident
    mask = slots >= 0
    safe = jnp.where(mask, slots, 0)
    rows = x[safe]                          # gather
    out_ref[...] = jnp.where(mask[:, None], rows, 0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def moe_gather_kernel(x: jax.Array, slot_token: jax.Array,
                      interpret: bool | None = None) -> jax.Array:
    """x: (T, D) tokens; slot_token: (S,) token id per expert slot (-1 =
    empty). Returns (S, D) expert-buffer rows."""
    interpret = runtime.interpret_mode(interpret)
    s = slot_token.shape[0]
    t, d = x.shape
    padded = -(-s // TILE_S) * TILE_S
    st = jnp.concatenate([slot_token.astype(jnp.int32),
                          jnp.full((padded - s,), -1, jnp.int32)])
    out = runtime.pallas_call(
        _kernel,
        name="moe_gather",
        grid=(padded // TILE_S,),
        in_specs=[pl.BlockSpec((TILE_S,), lambda i: (i,)),
                  pl.BlockSpec((t, d), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((TILE_S, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, d), x.dtype),
        interpret=interpret,
    )(st, x)
    return out[:s]
