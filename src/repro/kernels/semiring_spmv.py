"""Pallas kernel: fused masked-semiring SpMV/SpMM row sweep.

This absorbs the old ``kernels/spmv.py`` ELL kernel (plus-times only,
unmasked, single dense vector) and generalizes it into the algebra
layer's one row kernel:

  * any named semiring (``repro.linalg.semiring``) — the ⊗ combine and
    ⊕ row-reduction are selected at trace time (the semiring is a static,
    hashable argument), so each algebra compiles to a straight-line VPU
    kernel with zero runtime branching: Gunrock's compile-time functor
    fusion (§5.3) applied to the algebraic operator set;
  * a row mask (GraphBLAS's output mask): masked-out rows write the
    semiring's ⊕-identity and skip nothing structurally (dense VPU tiles
    can't skip lanes) but cost no extra memory traffic;
  * a dense multi-column operand X (nx, k): the grid gains an explicit
    leading column axis — the same (B, tiles) grid discipline as
    ``advance_fused.advance_fused_batch_kernel``, with B = dense columns
    (one batched reachability lane / label block per column).

TPU adaptation (unchanged from the absorbed kernel): CSR's ragged rows
are packed to ELL width W chosen at Graph build time; overflow edges of
ultra-high-degree rows are handled by a segment-reduce fallback in
``kernels/ops.py`` (the classic ELL+COO hybrid, now semiring-generic).

  y[i, b] = ⊕_w  vals[i, w] ⊗ x[nbrs[i, w], b]     (nbrs −1 ⇒ padding)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import runtime, tuner

TILE_R = 256        # heuristic floor; the tuner may pick larger tiles
MAX_GRID = 256


def _row_kernel(nbrs_ref, vals_ref, mask_ref, x_ref, y_ref, *, sr):
    nbrs = nbrs_ref[...]                   # (TILE, W) int32
    vals = vals_ref[...]                   # (TILE, W) f32
    rowm = mask_ref[...]                   # (TILE,) int32 (1 = compute)
    x = x_ref[...]                         # (nx, 1) f32 — column-resident
    ok = nbrs >= 0
    g = x[jnp.where(ok, nbrs, 0), 0]       # VPU gather
    prod = sr.mul_op(vals, g)              # ⊗, selected at trace time
    prod = jnp.where(ok, prod, sr.zero)
    red = sr.add_reduce(prod, axis=1)      # ⊕ row reduction
    y_ref[...] = jnp.where(rowm > 0, red, sr.zero)[:, None]


@functools.partial(jax.jit,
                   static_argnames=("semiring", "interpret", "tile"))
def semiring_ell_kernel(nbrs: jax.Array, vals: jax.Array, x: jax.Array,
                        mask: jax.Array, semiring,
                        interpret: bool | None = None,
                        tile: int | None = None) -> jax.Array:
    """nbrs/vals: (n, W); x: (nx, k); mask: (n,) int32. Returns (n, k) f32.

    One program per (column, row-tile) — grid (k, tiles). The dense
    column block and the CSR-derived ELL tiles are VMEM-resident per
    program; the semiring is static so the combine/reduce lower to fixed
    VPU ops.
    """
    interpret = runtime.interpret_mode(interpret)
    n, w = nbrs.shape
    nx, k = x.shape
    if tile is None:
        tile = tuner.tile_for("spmv", n, lanes=k, min_tile=TILE_R,
                              max_grid=MAX_GRID)
    padded = -(-n // tile) * tile
    if padded != n:
        pad = padded - n
        nbrs = jnp.concatenate([nbrs, jnp.full((pad, w), -1, nbrs.dtype)])
        vals = jnp.concatenate([vals, jnp.zeros((pad, w), vals.dtype)])
        mask = jnp.concatenate([mask, jnp.zeros((pad,), mask.dtype)])
    grid = (k, padded // tile)
    y = runtime.pallas_call(
        functools.partial(_row_kernel, sr=semiring),
        name="semiring_ell",
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, w), lambda b, t: (t, 0)),
            pl.BlockSpec((tile, w), lambda b, t: (t, 0)),
            pl.BlockSpec((tile,), lambda b, t: (t,)),
            pl.BlockSpec((nx, 1), lambda b, t: (0, b)),
        ],
        out_specs=pl.BlockSpec((tile, 1), lambda b, t: (t, b)),
        out_shape=jax.ShapeDtypeStruct((padded, k), jnp.float32),
        interpret=interpret,
    )(nbrs, vals.astype(jnp.float32), mask.astype(jnp.int32),
      x.astype(jnp.float32))
    return y[:n]
