"""Pallas kernel: ELL-format SpMV (PageRank advance; paper §6.5 notes PR is
congruent to SpMV, and nvGRAPH's semiring SpMV is a comparison point).

TPU adaptation: CSR's ragged rows can't tile onto the VPU, so rows are
packed to ELL width W (hybrid: overflow edges of ultra-high-degree
vertices are handled by a segment-sum fallback in ops.py — the classic
ELL+COO hybrid). The kernel streams row tiles; the dense x vector stays
VMEM-resident across the grid.

y[i] = Σ_w vals[i, w] · x[nbrs[i, w]]      (nbrs −1 ⇒ padding)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 256


def _kernel(nbrs_ref, vals_ref, x_ref, y_ref):
    nbrs = nbrs_ref[...]                   # (TILE_R, W) int32
    vals = vals_ref[...]                   # (TILE_R, W) f32
    x = x_ref[...]                         # (n,) f32 — resident
    mask = nbrs >= 0
    safe = jnp.where(mask, nbrs, 0)
    gathered = x[safe]                     # VPU gather (dynamic-slice loop
    #                                        under Mosaic; exact in interpret)
    y_ref[...] = jnp.sum(jnp.where(mask, vals * gathered, 0.0), axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmv_ell_kernel(nbrs: jax.Array, vals: jax.Array, x: jax.Array,
                    interpret: bool = True) -> jax.Array:
    """nbrs/vals: (n, W); x: (nx,). Returns y: (n,) float32."""
    n, w = nbrs.shape
    padded = -(-n // TILE_R) * TILE_R
    if padded != n:
        pad = padded - n
        nbrs = jnp.concatenate([nbrs, jnp.full((pad, w), -1, nbrs.dtype)])
        vals = jnp.concatenate([vals, jnp.zeros((pad, w), vals.dtype)])
    grid = (padded // TILE_R,)
    y = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_R, w), lambda i: (i, 0)),
            pl.BlockSpec((TILE_R, w), lambda i: (i, 0)),
            pl.BlockSpec(x.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_R,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        interpret=interpret,
    )(nbrs, vals.astype(jnp.float32), x.astype(jnp.float32))
    return y[:n]
