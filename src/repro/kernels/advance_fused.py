"""Pallas kernel: fused load-balanced advance (paper §5.1.3 + §5.3).

The unfused pipeline is lb_expand (binary search of the degree prefix
sum) followed by three separate gathers (base vertex, row offset, column
index) and a mask pass — five HBM round trips per advance. Gunrock fuses
its functors into the traversal kernel at compile time; this kernel is
the TPU analogue for the traversal itself: one ``pallas_call`` performs
the LB sorted search *and* the CSR gathers and emits the whole
``(src, dst, edge_id, in_pos, rank, valid)`` edge tuple in a single pass.

Memory layout (one program per output tile):
  offsets     (cap_in+1,) VMEM-resident, broadcast BlockSpec (block 0 for
              every program) — the degree prefix sum the search runs on.
  base        (cap_in,)   VMEM-resident broadcast — frontier base vertices.
  row_offsets (n+1,)      VMEM-resident broadcast — CSR row starts.
  col_indices (m,)        VMEM-resident broadcast — CSR neighbor IDs.
  outputs     6 × (tile,) streamed, one tile per program.

Same shape discipline as ``lb_expand_kernel``: 1-D tiles, int32 lanes,
every lane runs the identical ceil(log2(cap_in)) compare steps (fully
regular VPU work — the merge-path partitioning of Davidson et al. with
the divergence removed).

Tile sizes come from the autotuner (``kernels.tuner``): a measured
(op, tier, platform) cache entry when one exists, else the clamped
default heuristic — a tile never exceeds the padded output size, so a
small capacity tier cannot inflate VMEM block sizes past what it uses.

``advance_fused_batch_kernel`` is the multi-source variant: the grid gains
an explicit leading batch-row dimension (B, tiles). Each program serves
one (lane, tile) pair; the per-lane prefix sum and base vertices arrive as
(1, cap_in±1) row blocks indexed by the batch coordinate while the CSR
stays a broadcast block shared by every lane — B traversals expand in one
pallas_call with zero per-lane retracing.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import runtime, tuner


def _lb_body(offsets, base, row_offsets, col_indices, slots,
             *, cap_in: int, num_edges: int, iters: int, anchor=None):
    """Shared kernel body: LB sorted search + fused CSR gathers for one
    tile of output slots. Returns the six masked output vectors; the
    single-lane and batched kernels differ only in how they slice their
    refs around this.

    ``anchor`` selects the column decode (the PR 6 storage plan): when
    None, ``col_indices`` is the dense neighbor array (any int dtype,
    widened to int32 after the gather). When given, ``col_indices`` is
    the uint16 anchored-delta stream and ``anchor`` the (n,) int32
    first-neighbor array — the destination decode is one extra VMEM
    gather, ``dst = anchor[src] + delta[eid]``, and the row id it needs
    is the ``src`` the LB search just produced, so the decode rides the
    existing dataflow for free. Escaped streams never reach the kernel
    (the wrapper falls back to the decoded dense view)."""
    total = offsets[cap_in]
    tile = slots.shape[0]

    # LB sorted search: upper-bound binary search over the prefix sum.
    lo = jnp.zeros((tile,), jnp.int32)
    hi = jnp.full((tile,), cap_in, jnp.int32)

    def body(_, carry):
        lo_, hi_ = carry
        mid = (lo_ + hi_) // 2
        go_right = offsets[jnp.clip(mid, 0, cap_in)] <= slots
        lo_ = jnp.where(go_right & (lo_ < hi_), mid + 1, lo_)
        hi_ = jnp.where(~go_right & (lo_ < hi_), mid, hi_)
        return lo_, hi_

    lo, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    pos = jnp.clip(lo - 1, 0, max(cap_in - 1, 0))
    rank = slots - offsets[pos]
    valid = slots < total

    # fused CSR gathers (the formerly separate XLA passes)
    src = base[pos]
    eid = row_offsets[src] + rank
    eid = jnp.where(valid, eid, 0)
    col = col_indices[jnp.clip(eid, 0, max(num_edges - 1, 0))]
    if anchor is None:
        dst = col.astype(jnp.int32)
    else:
        dst = anchor[src] + col.astype(jnp.int32)

    return (jnp.where(valid, src, -1), jnp.where(valid, dst, -1),
            jnp.where(valid, eid, -1), pos, jnp.where(valid, rank, 0),
            valid.astype(jnp.int32))


def _kernel(offsets_ref, base_ref, ro_ref, ci_ref, anchor_ref,
            src_ref, dst_ref, eid_ref, ipos_ref, rank_ref, valid_ref,
            *, cap_in: int, num_edges: int, iters: int, tile: int,
            encoded: bool):
    t = pl.program_id(0)
    slots = t * tile + jax.lax.iota(jnp.int32, tile)
    src, dst, eid, pos, rank, valid = _lb_body(
        offsets_ref[...], base_ref[...], ro_ref[...], ci_ref[...], slots,
        cap_in=cap_in, num_edges=num_edges, iters=iters,
        anchor=anchor_ref[...] if encoded else None)
    src_ref[...] = src
    dst_ref[...] = dst
    eid_ref[...] = eid
    ipos_ref[...] = pos
    rank_ref[...] = rank
    valid_ref[...] = valid


def _split_store(col_indices):
    """Kernel operands ``(ci, anchor, encoded)`` for a column store.
    Dense arrays pass through with a dummy anchor; an escape-free delta
    stream splits into (uint16 deltas, int32 anchors); a stream WITH
    escapes decodes to dense right here — the wrapper-level fallback, so
    the kernel body never needs the sorted-side-list fixup."""
    from repro.core import storage as S
    if isinstance(col_indices, S.EncodedCols):
        if col_indices.num_escapes:
            return (S.decode_cols(col_indices),
                    jnp.zeros((1,), jnp.int32), False)
        return col_indices.delta, col_indices.anchor, True
    return col_indices, jnp.zeros((1,), jnp.int32), False


@functools.partial(jax.jit,
                   static_argnames=("cap_out", "interpret", "tile"))
def advance_fused_kernel(offsets: jax.Array, base: jax.Array,
                         row_offsets: jax.Array, col_indices,
                         cap_out: int, interpret: bool | None = None,
                         tile: int | None = None):
    """One-pass LB advance.

    offsets:     (cap_in+1,) int32 exclusive prefix sum of masked degrees
                 (total in the last slot).
    base:        (cap_in,) int32 base vertex of each input lane (invalid
                 lanes must carry a safe in-range id, e.g. 0).
    row_offsets: (n+1,) int32 CSR offsets.
    col_indices: (m,) int CSR neighbor ids (m ≥ 1; int16/int32 widen
                 in-kernel after the gather) or a ``storage.EncodedCols``
                 delta stream — decoded in place by the kernel (see
                 ``_lb_body``), streaming uint16 instead of the dense
                 dtype per edge.

    Returns (src, dst, edge_id, in_pos, rank, valid) each (cap_out,) with
    src/dst/edge_id == -1 and rank == 0 on invalid lanes, plus total ()
    int32.

    VMEM residency limit: the whole CSR (row_offsets + col_indices) must
    fit in VMEM (~16 MB/core ⇒ roughly m ≤ 4M edges at int32). The
    CPU-scaled dataset zoo is far below that; graphs beyond it need a
    future HBM-resident variant with manual DMA over edge windows.
    """
    interpret = runtime.interpret_mode(interpret)
    cap_in = offsets.shape[0] - 1
    ci, anchor, encoded = _split_store(col_indices)
    m = ci.shape[0]
    if tile is None:
        tile = tuner.tile_for("advance", cap_out,
                              encoding="delta" if encoded else "dense")
    padded = -(-cap_out // tile) * tile
    iters = max(math.ceil(math.log2(max(cap_in, 2))) + 1, 1)
    grid = (padded // tile,)
    out_shape = [jax.ShapeDtypeStruct((padded,), jnp.int32)] * 6
    bcast = lambda shape: pl.BlockSpec(shape, lambda i: (0,))
    src, dst, eid, ipos, rank, valid = runtime.pallas_call(
        functools.partial(_kernel, cap_in=cap_in, num_edges=m, iters=iters,
                          tile=tile, encoded=encoded),
        name="advance_fused",
        grid=grid,
        in_specs=[bcast((cap_in + 1,)), bcast((cap_in,)),
                  bcast(row_offsets.shape), bcast(ci.shape),
                  bcast(anchor.shape)],
        out_specs=[pl.BlockSpec((tile,), lambda i: (i,))] * 6,
        out_shape=out_shape,
        interpret=interpret,
    )(offsets, base, row_offsets, ci, anchor)
    return (src[:cap_out], dst[:cap_out], eid[:cap_out], ipos[:cap_out],
            rank[:cap_out], valid[:cap_out], offsets[-1])


def _batch_kernel(offsets_ref, base_ref, ro_ref, ci_ref, anchor_ref,
                  src_ref, dst_ref, eid_ref, ipos_ref, rank_ref, valid_ref,
                  *, cap_in: int, num_edges: int, iters: int, tile: int,
                  encoded: bool):
    """Same body as ``_kernel`` with a leading batch-row grid axis: refs
    carry (1, ·) row blocks selected by program_id(0)."""
    t = pl.program_id(1)
    slots = t * tile + jax.lax.iota(jnp.int32, tile)
    src, dst, eid, pos, rank, valid = _lb_body(
        offsets_ref[0, :], base_ref[0, :], ro_ref[0, :], ci_ref[0, :],
        slots, cap_in=cap_in, num_edges=num_edges, iters=iters,
        anchor=anchor_ref[0, :] if encoded else None)
    src_ref[0, :] = src
    dst_ref[0, :] = dst
    eid_ref[0, :] = eid
    ipos_ref[0, :] = pos
    rank_ref[0, :] = rank
    valid_ref[0, :] = valid


@functools.partial(jax.jit,
                   static_argnames=("cap_out", "interpret", "tile"))
def advance_fused_batch_kernel(offsets: jax.Array, base: jax.Array,
                               row_offsets: jax.Array,
                               col_indices,
                               cap_out: int, interpret: bool | None = None,
                               tile: int | None = None):
    """Multi-source one-pass LB advance over a (B, tiles) grid.

    offsets: (B, cap_in+1) int32 per-lane exclusive degree prefix sums.
    base:    (B, cap_in)   int32 per-lane base vertices (invalid lanes 0).
    row_offsets / col_indices: shared CSR, broadcast to every program.

    Returns (src, dst, edge_id, in_pos, rank, valid) each (B, cap_out)
    plus totals (B,) int32 — the batched registry contract.
    """
    interpret = runtime.interpret_mode(interpret)
    b, cap_in1 = offsets.shape
    cap_in = cap_in1 - 1
    ci, anchor, encoded = _split_store(col_indices)
    m = ci.shape[0]
    if tile is None:
        tile = tuner.tile_for("advance", cap_out, lanes=b,
                              encoding="delta" if encoded else "dense")
    padded = -(-cap_out // tile) * tile
    iters = max(math.ceil(math.log2(max(cap_in, 2))) + 1, 1)
    grid = (b, padded // tile)
    out_shape = [jax.ShapeDtypeStruct((b, padded), jnp.int32)] * 6
    row = lambda shape: pl.BlockSpec((1,) + shape, lambda bi, ti: (bi, 0))
    bcast = lambda shape: pl.BlockSpec((1,) + shape, lambda bi, ti: (0, 0))
    src, dst, eid, ipos, rank, valid = runtime.pallas_call(
        functools.partial(_batch_kernel, cap_in=cap_in, num_edges=m,
                          iters=iters, tile=tile, encoded=encoded),
        name="advance_fused_batch",
        grid=grid,
        in_specs=[row((cap_in + 1,)), row((cap_in,)),
                  bcast(row_offsets.shape), bcast(ci.shape),
                  bcast(anchor.shape)],
        out_specs=[pl.BlockSpec((1, tile), lambda bi, ti: (bi, ti))] * 6,
        out_shape=out_shape,
        interpret=interpret,
    )(offsets, base, row_offsets[None, :], ci[None, :], anchor[None, :])
    return (src[:, :cap_out], dst[:, :cap_out], eid[:, :cap_out],
            ipos[:, :cap_out], rank[:, :cap_out], valid[:, :cap_out],
            offsets[:, -1])
