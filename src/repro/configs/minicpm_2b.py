"""MiniCPM-2B (arXiv:2404.06395; hf-verified). Llama-like: 40L, d=2304,
36H (MHA kv=36), ff=5760, vocab=122753 (padded to 122880 for sharding),
tied embeddings. Trains with the WSD schedule (train config default)."""
import jax.numpy as jnp

from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab=122753, head_dim=64, rope_theta=10000.0,
    norm="rmsnorm", mlp="swiglu", tie_embeddings=True,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
    source="arXiv:2404.06395; hf",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512,
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none")
