"""Assigned input-shape set (same four for every LM arch).

``kind`` selects what gets lowered: train_step for training shapes,
serve prefill/decode for inference shapes (decode_* / long_* lower
``serve_step`` — one new token against a seq_len KV cache).
"""

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

# long_500k needs sub-quadratic attention: run only for SSM/hybrid
# families; pure full-attention archs skip it (DESIGN.md §Arch-applicability)
LONG_OK_FAMILIES = ("ssm", "hybrid")


def shapes_for(cfg) -> dict:
    out = dict(SHAPES)
    if cfg.family not in LONG_OK_FAMILIES:
        out.pop("long_500k")
    return out
