"""Qwen3-MoE 235B-A22B (hf:Qwen/Qwen3-30B-A3B family scaling; hf-verified
family). 94L, d=4096, 64 q heads (GQA kv=4), 128 experts top-8,
per-expert hidden 1536, vocab 151936. head_dim=128 per the Qwen3 family.
"""
import jax.numpy as jnp

from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=0, d_expert=1536, n_experts=128, top_k=8,
    vocab=151936, head_dim=128, rope_theta=1000000.0,
    norm="rmsnorm", mlp="swiglu", tie_embeddings=False,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    n_experts=8, top_k=2, d_expert=32, vocab=512,
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none")
