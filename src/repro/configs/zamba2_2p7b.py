"""Zamba2-2.7B (arXiv:2411.15242; hf-verified). Hybrid: 54 Mamba2 layers
(d_state=64) + ONE shared attention+MLP block (32H MHA, ff=10240)
applied every 6 SSM layers (9 applications, weights shared). d=2560,
vocab=32000, head_dim=80. Simplification noted in DESIGN.md: shared
block consumes the hidden state only (no embedding concat)."""
import jax.numpy as jnp

from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, head_dim=80, rope_theta=10000.0,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    ssm_chunk=128, attn_every=6,
    norm="rmsnorm", mlp="swiglu", tie_embeddings=True,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
    source="arXiv:2411.15242; hf",
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512, ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
    attn_every=2,
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none")
