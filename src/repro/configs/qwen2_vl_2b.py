"""Qwen2-VL-2B (arXiv:2409.12191; hf-verified). 28L, d=1536, 12H
(GQA kv=2), ff=8960, vocab=151936; M-RoPE sections (16, 24, 24) over
head_dim/2 = 64 pairs; attention biases; tied embeddings.

The vision frontend (ViT patch encoder, dynamic resolution) is a STUB:
input_specs() supplies precomputed patch/frame embeddings plus the 3-D
M-RoPE position ids the frontend would emit.
"""
import jax.numpy as jnp

from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936, head_dim=128, rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),
    norm="rmsnorm", mlp="swiglu", attn_bias=True, tie_embeddings=True,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
    source="arXiv:2409.12191; hf",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, mrope_sections=(2, 3, 3),
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none")
