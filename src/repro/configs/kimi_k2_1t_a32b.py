"""Kimi K2 — trillion-parameter MoE (arXiv:2501.kimi2; paper-table,
unverified). 61L, d=7168, 64 q heads (GQA kv=8), 384 experts top-8,
per-expert FFN hidden 2048, vocab 163840.

Assumptions (fields the assignment doesn't pin): head_dim = d/H = 112,
rope_theta = 50000, one shared expert (common for fine-grained MoE;
excluded here — assignment lists pure 384e top-8), untied embeddings.
"""
import jax.numpy as jnp

from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=0, d_expert=2048, n_experts=384, top_k=8,
    vocab=163840, head_dim=112, rope_theta=50000.0,
    norm="rmsnorm", mlp="swiglu", tie_embeddings=False,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
    source="arXiv:2501.kimi2; unverified",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    n_experts=8, top_k=2, d_expert=32, vocab=512,
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none")
