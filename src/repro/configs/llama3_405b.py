"""Llama-3.1 405B (arXiv:2407.21783; unverified). 126L, d=16384,
128H (GQA kv=8), ff=53248, vocab=128256, rope_theta=500000."""
import jax.numpy as jnp

from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab=128256, head_dim=128, rope_theta=500000.0,
    norm="rmsnorm", mlp="swiglu", tie_embeddings=False,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
    source="arXiv:2407.21783; unverified",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none")
