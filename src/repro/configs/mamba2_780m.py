"""Mamba2-780m (arXiv:2405.21060; unverified). Attention-free SSD:
48L, d=1536, d_state=128, expand=2 (d_inner=3072), ssd head_dim=64
(48 heads), conv=4, vocab=50280 (padded to 50432), tied embeddings."""
import jax.numpy as jnp

from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_conv=4, ssm_expand=2,
    ssm_head_dim=64, ssm_chunk=128,
    norm="rmsnorm", tie_embeddings=True,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
    source="arXiv:2405.21060; unverified",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
    vocab=512,
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none")
