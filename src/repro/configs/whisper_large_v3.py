"""Whisper-large-v3 (arXiv:2212.04356; unverified). Enc-dec: 32+32L,
d=1280, 20H (MHA kv=20), ff=5120, vocab=51866 (padded 51968);
LayerNorm + GELU, sinusoidal positions, conv/mel frontend STUBBED
(input_specs provides precomputed frame embeddings, 1500 frames = 30 s).
"""
import jax.numpy as jnp

from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, n_enc_layers=32, n_dec_layers=32,
    d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, head_dim=64,
    norm="layernorm", mlp="gelu", attn_bias=True,
    max_source_len=1500,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
    source="arXiv:2212.04356; unverified",
)

SMOKE = CONFIG.replace(
    n_layers=2, n_enc_layers=2, n_dec_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, vocab=512, max_source_len=32,
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none")
