"""Architecture config registry: ``get_config(id)`` / ``get_smoke_config``.

Each <arch>.py holds the exact assigned full config (CONFIG) and a reduced
same-family smoke variant (SMOKE) for CPU tests.
"""
from __future__ import annotations

import importlib

from .shapes import SHAPES, shapes_for

_MODULES = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "yi-6b": "yi_6b",
    "llama3-405b": "llama3_405b",
    "starcoder2-15b": "starcoder2_15b",
    "minicpm-2b": "minicpm_2b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "mamba2-780m": "mamba2_780m",
    "whisper-large-v3": "whisper_large_v3",
    "zamba2-2.7b": "zamba2_2p7b",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str):
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str):
    return _module(arch_id).SMOKE


__all__ = ["ARCH_IDS", "SHAPES", "get_config", "get_smoke_config",
           "shapes_for"]
