"""StarCoder2-15B (arXiv:2402.19173; hf-verified). 40L, d=6144,
48H (GQA kv=4), ff=24576, vocab=49152; LayerNorm + GELU, attention
biases, rope_theta=100000."""
import jax.numpy as jnp

from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
    d_ff=24576, vocab=49152, head_dim=128, rope_theta=100000.0,
    norm="layernorm", mlp="gelu", attn_bias=True, tie_embeddings=False,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
    source="arXiv:2402.19173; hf",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none")
