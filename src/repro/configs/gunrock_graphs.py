"""The paper's own workload configs (Table 4), CPU-scaled.

Each entry maps a paper dataset to a generator recipe of the same family
(scale-free social / web-crawl / generated R-MAT with Graph500
initiators / random geometric / road mesh), at sizes this container can
run. `scaled_by` records the size reduction vs the paper's graph.
"""
from __future__ import annotations

from repro.core import graph as G

PAPER_DATASETS = {
    # paper name          family        generator                     scaled_by
    "soc-orkut": dict(
        family="real scale-free social",
        make=lambda: G.rmat(14, 16, seed=101, weighted=True),
        paper_nm=(3.0e6, 212.7e6), scaled_by="~800x"),
    "soc-livejournal1": dict(
        family="real scale-free social",
        make=lambda: G.rmat(14, 8, seed=102, weighted=True),
        paper_nm=(4.8e6, 85.7e6), scaled_by="~650x"),
    "hollywood-09": dict(
        family="real scale-free collab",
        make=lambda: G.rmat(13, 16, seed=103, weighted=True),
        paper_nm=(1.1e6, 112.8e6), scaled_by="~860x"),
    "indochina-04": dict(
        family="web crawl (very skewed)",
        make=lambda: G.rmat(14, 8, a=0.65, b=0.15, c=0.15, seed=104,
                            weighted=True),
        paper_nm=(7.4e6, 302e6), scaled_by="~2300x"),
    "rmat_s22_e64": dict(
        family="generated R-MAT (Graph500 initiators)",
        make=lambda: G.rmat(14, 32, seed=105, weighted=True),
        paper_nm=(4.2e6, 483e6), scaled_by="~920x"),
    "rmat_s23_e32": dict(
        family="generated R-MAT",
        make=lambda: G.rmat(15, 16, seed=106, weighted=True),
        paper_nm=(8.4e6, 505.6e6), scaled_by="~960x"),
    "rmat_s24_e16": dict(
        family="generated R-MAT",
        make=lambda: G.rmat(16, 8, seed=107, weighted=True),
        paper_nm=(16.8e6, 519.7e6), scaled_by="~990x"),
    "rgg_n_24": dict(
        family="random geometric (mesh-like)",
        make=lambda: G.random_geometric(1 << 14, 0.013, seed=108,
                                        weighted=True),
        paper_nm=(16.8e6, 265.1e6), scaled_by="~1000x"),
    "roadnet_USA": dict(
        family="road network (mesh-like)",
        make=lambda: G.grid2d(128, weighted=True, seed=109),
        paper_nm=(23.9e6, 577.1e6), scaled_by="~1400x"),
}


def make_paper_dataset(name: str):
    return PAPER_DATASETS[name]["make"]()
