"""Yi-6B (arXiv:2403.04652; hf-verified). Llama-arch GQA: 32L, d=4096,
32H (kv=4), ff=11008, vocab=64000, rope_theta=5e6."""
import jax.numpy as jnp

from repro.models.api import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab=64000, head_dim=128, rope_theta=5000000.0,
    norm="rmsnorm", mlp="swiglu", tie_embeddings=False,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat="full",
    source="arXiv:2403.04652; hf",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none")
